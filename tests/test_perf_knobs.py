"""§Perf knob correctness: every optimization must preserve model math."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.modeling.registry import build_model
from repro.training.data import make_pipeline
from repro.training.optimizer import OptimizerConfig
from repro.training.train_loop import init_train_state, make_train_step


def _loss_for(cfg, params, batch):
    model = build_model(cfg)
    loss, _ = model.loss(params, batch)
    return float(loss)


@pytest.mark.parametrize("arch,updates", [
    ("llama3.2-1b", {"loss_impl": "gather"}),
    ("gemma-2b", {"loss_impl": "gather"}),
    ("llama3.2-1b", {"cp_attn": True}),          # no mesh → ways=0 → plain path
])
def test_knobs_loss_invariant(arch, updates, rng):
    base = smoke_config(arch)
    model = build_model(base)
    params = model.init(jax.random.key(0))
    pipe = make_pipeline(base, 32, 2, 0)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()}
    l0 = _loss_for(base, params, batch)
    l1 = _loss_for(base.with_updates(**updates), params, batch)
    assert abs(l0 - l1) < 1e-5, (arch, updates)


def test_banded_window_loss_invariant(rng):
    base = smoke_config("recurrentgemma-9b").with_updates(attn_window=8,
                                                          q_chunk=8)
    model = build_model(base)
    params = model.init(jax.random.key(0))
    pipe = make_pipeline(base, 32, 2, 0)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()}
    l0 = _loss_for(base, params, batch)
    l1 = _loss_for(base.with_updates(banded_window=True), params, batch)
    assert abs(l0 - l1) < 1e-5


def test_microbatch_bitexact():
    cfg = smoke_config("llama3.2-1b")
    model = build_model(cfg)
    pipe = make_pipeline(cfg, 32, 4, 0)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()}

    def one_step(mb):
        m = build_model(cfg.with_updates(microbatch=mb))
        params, state = init_train_state(m, jax.random.key(1))
        step = make_train_step(m, OptimizerConfig())
        p, _, metrics = step(params, {"opt": state["opt"]}, batch)
        return p, float(metrics["loss"])

    p1, l1 = one_step(1)
    p2, l2 = one_step(2)
    assert abs(l1 - l2) < 1e-5
    for k in p1:
        np.testing.assert_allclose(np.asarray(p1[k]), np.asarray(p2[k]),
                                   atol=5e-5)


def test_moe_batch_groups_routing_consistent(rng):
    """Decode-time batch grouping must route each token to the same experts
    it would get in its own group (capacity permitting)."""
    cfg = smoke_config("olmoe-1b-7b").with_updates(capacity_factor=8.0)
    cfg_bg = cfg.with_updates(moe_batch_groups=True)
    m0, m1 = build_model(cfg), build_model(cfg_bg)
    params = m0.init(jax.random.key(0))
    B, S = 4, 1
    batch = {"tokens": jnp.asarray(rng.integers(2, 100, (B, S)), jnp.int32)}
    l0, c0 = m0.prefill(params, batch, cache_len=8)
    l1, c1 = m1.prefill(params, batch, cache_len=8)
    t = jnp.zeros((B,), jnp.int32)
    d0, _ = m0.decode_step(params, c0, {"token": t})
    d1, _ = m1.decode_step(params, c1, {"token": t})
    # generous capacity ⇒ no drops in either layout ⇒ identical logits
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1), rtol=2e-4,
                               atol=2e-4)


def test_rglru_block_gates_structure():
    cfg = smoke_config("recurrentgemma-9b").with_updates(rglru_block_gates=4)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    gate_keys = [k for k in params if k.endswith("gate_a/w")]
    assert gate_keys
    for k in gate_keys:
        assert params[k].ndim == 4  # (layers, nb, dr/nb, dr/nb)
    pipe = make_pipeline(cfg, 32, 2, 0)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()}
    loss, _ = model.loss(params, batch)
    assert np.isfinite(float(loss))


def test_kv_quant_decode_close(rng):
    cfg = smoke_config("llama3.2-1b")
    m0 = build_model(cfg)
    m1 = build_model(cfg.with_updates(kv_quant=True))
    params = m0.init(jax.random.key(0))
    B, S = 2, 12
    batch = {"tokens": jnp.asarray(rng.integers(2, 100, (B, S)), jnp.int32)}
    l0, c0 = m0.prefill(params, batch, cache_len=S + 4)
    l1, c1 = m1.prefill(params, batch, cache_len=S + 4)
    assert c1["k"].dtype == jnp.int8 and "k_scale" in c1
    for _ in range(4):
        tok = jnp.argmax(l0, -1).astype(jnp.int32)
        l0, c0 = m0.decode_step(params, c0, {"token": tok})
        l1, c1 = m1.decode_step(params, c1, {"token": tok})
    scale = float(jnp.max(jnp.abs(l0)))
    assert float(jnp.max(jnp.abs(l0 - l1))) / scale < 0.02
