# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# host's real device count (1 CPU device); only launch/dryrun.py forces 512.
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
