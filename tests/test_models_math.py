"""Model math: chunked vs. naive paths, MoE invariants, prefill/decode parity."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.modeling.attention import chunked_attention, decode_attention
from repro.modeling.losses import chunked_softmax_xent, full_softmax_xent
from repro.modeling.moe import moe_apply, moe_capacity, moe_specs
from repro.modeling.registry import build_model
from repro.modeling.rglru import causal_conv1d, rglru_scan
from repro.modeling.ssd import ssd_chunked, ssd_naive
from repro.kernels.flash_attention.ref import attention_ref


# ------------------------------------------------------------- attention
@pytest.mark.parametrize("q_chunk", [8, 32, 512])
@pytest.mark.parametrize("window", [0, 16])
def test_chunked_attention_matches_naive(q_chunk, window, rng):
    B, S, H, Hkv, D = 2, 48, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    out = chunked_attention(q, k, v, causal=True, window=window, q_chunk=q_chunk)
    ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_matches_full_row(rng):
    """Decoding position t must reproduce row t of full causal attention."""
    B, S, H, D = 1, 24, 2, 8
    q_all = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    full = chunked_attention(q_all, k, v, causal=True, q_chunk=8)
    t = 13
    dec = decode_attention(q_all[:, t:t + 1], k, v,
                           jnp.full((B,), t + 1, jnp.int32))
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, t]),
                               rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------------- loss
@pytest.mark.parametrize("chunk", [4, 16, 64])
def test_chunked_loss_matches_full(chunk, rng):
    B, S, D, V = 2, 32, 16, 50
    h = jnp.asarray(rng.normal(size=(B, S, D)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(D, V)), jnp.float32)
    t = jnp.asarray(rng.integers(0, V, size=(B, S)), jnp.int32)
    m = jnp.asarray(rng.random((B, S)) > 0.3, jnp.float32)
    ls, dn = chunked_softmax_xent(h, w, t, m, chunk=chunk)
    lf, df = full_softmax_xent(h, w, t, m)
    np.testing.assert_allclose(float(ls), float(lf), rtol=1e-5)
    np.testing.assert_allclose(float(dn), float(df), rtol=1e-6)


def test_chunked_loss_grad_matches_full(rng):
    B, S, D, V = 1, 16, 8, 20
    h = jnp.asarray(rng.normal(size=(B, S, D)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(D, V)), jnp.float32)
    t = jnp.asarray(rng.integers(0, V, size=(B, S)), jnp.int32)
    m = jnp.ones((B, S), jnp.float32)

    gc = jax.grad(lambda w: chunked_softmax_xent(h, w, t, m, chunk=4)[0])(w)
    gf = jax.grad(lambda w: full_softmax_xent(h, w, t, m)[0])(w)
    np.testing.assert_allclose(np.asarray(gc), np.asarray(gf), rtol=1e-4,
                               atol=1e-5)


# -------------------------------------------------------------------- SSD
def test_ssd_chunked_matches_naive(rng):
    b, S, nh, hd, ds = 2, 64, 2, 8, 8
    x = jnp.asarray(rng.normal(size=(b, S, nh, hd)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(b, S, nh))) * 0.4, jnp.float32)
    A = jnp.asarray([-0.3, -0.9], jnp.float32)
    B_ = jnp.asarray(rng.normal(size=(b, S, ds)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, S, ds)), jnp.float32)
    yc, sc = ssd_chunked(x, dt, A, B_, C, chunk=16)
    yn, sn = ssd_naive(x, dt, A, B_, C)
    np.testing.assert_allclose(np.asarray(yc), np.asarray(yn), rtol=1e-3,
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(sc), np.asarray(sn), rtol=1e-3,
                               atol=1e-3)


# ----------------------------------------------------------------- RG-LRU
def test_rglru_scan_matches_sequential(rng):
    B, S, D = 2, 33, 8
    x = jnp.asarray(rng.normal(size=(B, S, D)), jnp.float32)
    a = jnp.asarray(rng.uniform(0.1, 1.0, size=(B, S, D)), jnp.float32)
    h_scan = rglru_scan(x, a)
    h = np.zeros((B, D), np.float32)
    for t in range(S):
        h = np.asarray(a[:, t]) * h + np.asarray(x[:, t])
        np.testing.assert_allclose(np.asarray(h_scan[:, t]), h, rtol=1e-4,
                                   atol=1e-5)


def test_causal_conv1d_is_causal(rng):
    B, S, D, W = 1, 16, 4, 4
    x = jnp.asarray(rng.normal(size=(B, S, D)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(W, D)), jnp.float32)
    b = jnp.zeros((D,), jnp.float32)
    out1 = causal_conv1d(x, w, b)
    x2 = x.at[:, 10:].set(99.0)  # future perturbation
    out2 = causal_conv1d(x2, w, b)
    np.testing.assert_allclose(np.asarray(out1[:, :10]), np.asarray(out2[:, :10]),
                               rtol=1e-6)


# -------------------------------------------------------------------- MoE
def test_moe_capacity_and_dispatch_invariants(rng):
    cfg = smoke_config("olmoe-1b-7b")
    model = build_model(cfg)
    key = jax.random.key(0)
    from repro.modeling.module import init_params
    p = init_params(key, moe_specs(cfg))
    B, S, D = 2, 32, cfg.d_model
    x = jnp.asarray(rng.normal(size=(B, S, D)), jnp.float32)
    y, aux = moe_apply(cfg, p, x)
    assert y.shape == x.shape
    assert np.all(np.isfinite(np.asarray(y)))
    assert float(aux) > 0  # load-balance loss strictly positive for softmax router
    C = moe_capacity(cfg)
    assert C >= cfg.moe_group * cfg.top_k / cfg.n_experts  # >= mean load


def test_moe_identical_tokens_route_identically(rng):
    cfg = smoke_config("olmoe-1b-7b")
    from repro.modeling.module import init_params
    p = init_params(jax.random.key(0), moe_specs(cfg))
    x0 = jnp.asarray(rng.normal(size=(1, 1, cfg.d_model)), jnp.float32)
    x = jnp.tile(x0, (1, 4, 1))
    y, _ = moe_apply(cfg, p, x)
    # identical tokens within capacity → identical outputs
    ref = np.asarray(y[0, 0])
    for t in range(1, 3):  # later copies may be capacity-dropped; check first rows
        np.testing.assert_allclose(np.asarray(y[0, t]), ref, rtol=1e-4, atol=1e-5)


# -------------------------------------------- prefill/decode == forward parity
@pytest.mark.parametrize("arch", ["llama3.2-1b", "gemma-2b", "mamba2-780m",
                                  "recurrentgemma-9b", "olmoe-1b-7b"])
def test_prefill_then_decode_matches_forward(arch, rng):
    """Teacher-forced decode must reproduce the training forward logits."""
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(3))
    B, S = 1, 16
    toks = jnp.asarray(rng.integers(2, cfg.vocab, size=(B, S)), jnp.int32)

    # full forward logits at every position
    h, _ = model.forward(params, {"tokens": toks})
    w = model._unembed(params).astype(h.dtype)
    full_logits = jnp.einsum("bsd,dv->bsv", h, w)

    # prefill on the first k tokens, then teacher-forced decode
    k = 8
    logits, cache = model.prefill(params, {"tokens": toks[:, :k]}, cache_len=S)
    np.testing.assert_allclose(
        np.asarray(logits, np.float32), np.asarray(full_logits[:, k - 1], np.float32),
        rtol=2e-2, atol=2e-3)
    for t in range(k, S):
        logits, cache = model.decode_step(params, cache, {"token": toks[:, t]})
        np.testing.assert_allclose(
            np.asarray(logits, np.float32), np.asarray(full_logits[:, t], np.float32),
            rtol=2e-2, atol=2e-3,
            err_msg=f"{arch}: decode step {t} diverged from forward")
