"""Multi-edge fleet invariants (ISSUE 2) + columnar decision core (ISSUE 3).

Covers:
- ``EdgeFleet`` construction, replication, and validation;
- batched ``place_many`` vs per-task ``step`` decision equality on a 3-device
  fleet, and the full serve-loop batched/stepwise bitwise equivalence
  (decisions AND vectorized twin execution);
- ``TwinBackend.execute_many`` bitwise parity with the sequential ``execute``
  loop, including hedged duplicate dispatches;
- per-device RNG stream isolation: adding a device never perturbs another
  device's ground-truth draws (regression for the shared-stream coupling);
- balancers: least-predicted-wait beats round-robin on skewed arrivals, and
  both beat nothing — plus unit behavior of all three balancers;
- the deprecated single-edge ``Simulation`` wrapper still produces identical
  results to the fleet-of-one runtime;
- per-device utilization / queue-wait summaries on ``SimulationResult``;
- the batched GBRT path routed through the Pallas kernel agrees with the
  numpy tree walk (ROADMAP item).
"""

from __future__ import annotations

import numpy as np
import pytest

import repro.core.predictor as predictor_mod
from repro.core.decision import (
    DecisionEngine,
    HedgedPolicy,
    LeastPredictedWaitBalancer,
    MinCostPolicy,
    MinLatencyPolicy,
    RandomBalancer,
    RoundRobinBalancer,
)
from repro.core.fit import build_fleet_predictor, build_predictor, fit_app
from repro.core.predictor import EdgeFleet
from repro.core.runtime import PlacementRuntime, TwinBackend, edge_stream_key
from repro.core.simulator import Simulation
from repro.core.workload import BurstyWorkload

CONFIGS = (1280, 1536, 1792)
N_TASKS = 200
FLEET = {"edge0": 1.0, "edge1": 1.0, "edge2": 0.6}
NAMES = tuple(FLEET)


@pytest.fixture(scope="module")
def fd_setup():
    return fit_app("FD", seed=0, n_inputs=120, configs=CONFIGS)


@pytest.fixture(scope="module")
def ir_setup():
    return fit_app("IR", seed=0, n_inputs=120, configs=CONFIGS)


def _fleet_runtime(twin, models, c_max=2.97e-5, alpha=0.02, balancer=None,
                   seed=11):
    pred = build_fleet_predictor(models, dict(FLEET), configs=CONFIGS)
    kwargs = {"balancer": balancer} if balancer is not None else {}
    eng = DecisionEngine(predictor=pred,
                         policy=MinLatencyPolicy(c_max=c_max, alpha=alpha),
                         **kwargs)
    backend = TwinBackend(twin, seed=seed, edge_names=NAMES, edge_speed=FLEET)
    return PlacementRuntime(eng, backend)


# ----------------------------------------------------------------- EdgeFleet
def test_edge_fleet_validation(fd_setup):
    _, models = fd_setup
    base = build_predictor(models, configs=CONFIGS)
    template = base.edge_target
    fleet = EdgeFleet.replicate(template, 3, speeds={"edge2": 0.5})
    assert fleet.names == ("edge0", "edge1", "edge2")
    assert "edge1" in fleet and len(fleet) == 3
    # the slow device predicts proportionally longer compute
    t = 2.0e6
    assert fleet["edge2"].comp_model.predict(t) == pytest.approx(
        2.0 * fleet["edge0"].comp_model.predict(t))

    with pytest.raises(ValueError, match="duplicate"):
        EdgeFleet([template, template])

    class NotEdge:
        name = "x"
        is_edge = False

    with pytest.raises(ValueError, match="is_edge"):
        EdgeFleet([NotEdge()])


def test_fleet_arbitrary_device_names(fd_setup):
    """Heterogeneous fleets may use real device names, not just edge0..N."""
    twin, models = fd_setup
    devices = {"hub": 1.0, "cam-a": 0.5}
    pred = build_fleet_predictor(models, devices, configs=CONFIGS)
    assert pred.edge_names == ("hub", "cam-a")
    eng = DecisionEngine(predictor=pred,
                         policy=MinLatencyPolicy(c_max=0.0, alpha=0.0))
    backend = TwinBackend(twin, seed=3, edge_names=tuple(devices),
                          edge_speed=devices)
    res = PlacementRuntime(eng, backend).serve(twin.workload(30, seed=1))
    assert set(res.configs_used()) <= {"hub", "cam-a"}
    assert res.n_edge == 30


def test_cloud_only_runtime_edge_queue_alias(fd_setup):
    """The deprecated ``edge_queue`` alias must not crash without a fleet."""
    _, models = fd_setup
    from repro.core.predictor import Predictor

    base = build_predictor(models, configs=CONFIGS)
    pred = Predictor(cloud_targets=base.cloud_targets)
    eng = DecisionEngine(predictor=pred, policy=MinCostPolicy(deadline_ms=1e9))

    class _NullBackend:
        def probe_cold(self, target, now):
            return False

        def execute(self, task, target, now):
            from repro.core.runtime import ExecutionOutcome

            return ExecutionOutcome(1.0, 0.0, False, now + 1.0)

    rt = PlacementRuntime(eng, _NullBackend())
    assert rt.edge_queue.horizon_ms == 0.0
    from repro.core.workload import TaskInput

    res = rt.serve([TaskInput(idx=0, arrival_ms=0.0, size=1.0, bytes=1.0)])
    assert res.n == 1


def test_predictor_rejects_fleet_and_target(fd_setup):
    _, models = fd_setup
    base = build_predictor(models, configs=CONFIGS)
    from repro.core.predictor import Predictor

    with pytest.raises(ValueError, match="not both"):
        Predictor(cloud_targets=base.cloud_targets,
                  edge_target=base.edge_target,
                  edge_fleet=EdgeFleet.single(base.edge_target))


# ------------------------------------------- decision + execution equivalence
def test_fleet_place_many_matches_step(ir_setup):
    """Batched and per-task serve paths must make identical decisions on a
    3-device fleet — including which device the balancer nominated."""
    twin, models = ir_setup
    tasks = twin.workload(N_TASKS, seed=2)

    batched = _fleet_runtime(twin, models).serve(tasks, batched=True)
    stepwise = _fleet_runtime(twin, models).serve(tasks, batched=False)

    assert [r.target for r in batched.records] == \
        [r.target for r in stepwise.records]
    # bitwise: the vectorized twin sampler consumes the same RNG streams
    assert batched.total_actual_cost == stepwise.total_actual_cost
    assert batched.avg_actual_latency_ms == stepwise.avg_actual_latency_ms
    assert [r.queue_wait_ms for r in batched.records] == \
        [r.queue_wait_ms for r in stepwise.records]


def test_execute_many_bitwise_equals_execute_loop(ir_setup):
    twin, models = ir_setup
    tasks = twin.workload(N_TASKS, seed=3)
    eng = DecisionEngine(
        predictor=build_fleet_predictor(models, dict(FLEET), configs=CONFIGS),
        policy=MinLatencyPolicy(c_max=3e-6, alpha=0.02))
    targets = [d.target for d in eng.place_many(tasks)]
    assert len({t for t in targets if t in FLEET}) >= 2  # fleet actually used
    assert any(t not in FLEET for t in targets)          # cloud used too

    b_seq = TwinBackend(twin, seed=5, edge_names=NAMES, edge_speed=FLEET)
    outs = [b_seq.execute(t, tg, t.arrival_ms) for t, tg in zip(tasks, targets)]
    b_vec = TwinBackend(twin, seed=5, edge_names=NAMES, edge_speed=FLEET)
    batch = b_vec.execute_many(tasks, targets)
    assert len(batch) == len(outs)
    assert outs == batch.outcomes()
    assert outs[0] == batch[0]
    assert b_seq.edge_free_at == b_vec.edge_free_at


def test_hedged_fleet_serve_batched_equals_stepwise(fd_setup):
    """Hedged duplicates are executed in the same order on both paths."""
    twin, models = fd_setup
    tasks = twin.workload(150, seed=5)

    def run(batched):
        pred = build_fleet_predictor(models, dict(FLEET), configs=CONFIGS)
        policy = HedgedPolicy(MinLatencyPolicy(c_max=8e-5, alpha=0.0),
                              hedge_threshold_ms=1500.0)
        eng = DecisionEngine(predictor=pred, policy=policy)
        backend = TwinBackend(twin, seed=17, edge_names=NAMES, edge_speed=FLEET)
        return PlacementRuntime(eng, backend).serve(tasks, batched=batched)

    a, b = run(True), run(False)
    assert sum(r.hedged for r in a.records) > 0
    assert [r.target for r in a.records] == [r.target for r in b.records]
    assert a.total_actual_cost == b.total_actual_cost
    assert a.avg_actual_latency_ms == b.avg_actual_latency_ms
    # hedge legs are visible to the per-device load metrics
    hedged_on_fleet = [r for r in a.records if r.hedge_target in FLEET]
    if hedged_on_fleet:
        summaries = a.device_summaries()
        dev = hedged_on_fleet[0].hedge_target
        n_primary = sum(1 for r in a.records if r.target == dev)
        assert summaries[dev].n_tasks > n_primary


# -------------------------------------------------------- RNG stream isolation
def test_adding_device_never_perturbs_another_devices_draws(ir_setup):
    """Regression: per-device RNG streams are keyed by (seed, crc32(name)),
    so ground truth on device A is identical under any fleet composition."""
    twin, _ = ir_setup
    tasks = twin.workload(30, seed=6)
    two = TwinBackend(twin, seed=9, edge_names=("edge0", "edge1"))
    three = TwinBackend(twin, seed=9, edge_names=("edge0", "edge1", "edge2"))
    outs_two = [two.execute(t, "edge0", t.arrival_ms) for t in tasks]
    outs_three = [three.execute(t, "edge0", t.arrival_ms) for t in tasks]
    assert outs_two == outs_three


def test_edge_stream_key_stable():
    assert edge_stream_key("edge0") == edge_stream_key("edge0")
    assert edge_stream_key("edge0") != edge_stream_key("edge1")


# ----------------------------------------------------------------- balancers
def test_balancer_units():
    names = ("a", "b", "c")
    waits = {"a": 5.0, "b": 0.0, "c": 9.0}
    assert LeastPredictedWaitBalancer().pick(names, waits, {}) == "b"
    # ties break by fleet order
    assert LeastPredictedWaitBalancer().pick(names, {}, {}) == "a"
    rr = RoundRobinBalancer()
    assert [rr.pick(names, waits, {}) for _ in range(4)] == ["a", "b", "c", "a"]
    r1 = RandomBalancer(seed=3)
    r2 = RandomBalancer(seed=3)
    picks = [r1.pick(names, waits, {}) for _ in range(20)]
    assert picks == [r2.pick(names, waits, {}) for _ in range(20)]
    assert set(picks) == set(names)


def test_least_wait_beats_round_robin_on_skewed_arrivals(ir_setup):
    twin, models = ir_setup
    tasks = BurstyWorkload(rate_per_s=4.0, size_sampler=twin.sample_input,
                           burst_multiplier=6.0, mean_quiet_s=15.0,
                           mean_burst_s=6.0, seed=7).generate(1200)
    lpw = _fleet_runtime(twin, models, c_max=2e-6,
                         balancer=LeastPredictedWaitBalancer()).serve(tasks)
    rr = _fleet_runtime(twin, models, c_max=2e-6,
                        balancer=RoundRobinBalancer()).serve(tasks)
    assert lpw.avg_actual_latency_ms < rr.avg_actual_latency_ms
    assert lpw.p99_actual_latency_ms < rr.p99_actual_latency_ms


def test_fleet_beats_single_edge_on_skewed_arrivals(ir_setup):
    twin, models = ir_setup
    tasks = BurstyWorkload(rate_per_s=4.0, size_sampler=twin.sample_input,
                           burst_multiplier=6.0, mean_quiet_s=15.0,
                           mean_burst_s=6.0, seed=7).generate(1200)
    fleet = _fleet_runtime(twin, models, c_max=2e-6).serve(tasks)
    pred = build_predictor(models, configs=CONFIGS)
    eng = DecisionEngine(predictor=pred,
                         policy=MinLatencyPolicy(c_max=2e-6, alpha=0.02))
    single = PlacementRuntime(eng, TwinBackend(twin, seed=11)).serve(tasks)
    assert fleet.avg_actual_latency_ms < single.avg_actual_latency_ms


# ------------------------------------------------- single-edge back-compat
def test_single_edge_simulation_wrapper_identical(fd_setup):
    """The deprecated ``Simulation`` wrapper (one edge device) must produce
    results identical to the fleet-of-one runtime built explicitly."""
    twin, models = fd_setup
    tasks = twin.workload(100, seed=8)

    eng1 = DecisionEngine(predictor=build_predictor(models, configs=CONFIGS),
                          policy=MinCostPolicy(deadline_ms=4500.0))
    res1 = Simulation(twin, eng1, seed=13).run(tasks)

    pred = build_predictor(models, configs=CONFIGS)
    fleet_of_one = EdgeFleet.single(pred.edge_target)
    from repro.core.predictor import Predictor

    pred2 = Predictor(cloud_targets=pred.cloud_targets, edge_fleet=fleet_of_one,
                      cil=type(pred.cil)(t_idl_ms=pred.cil.t_idl_ms))
    eng2 = DecisionEngine(predictor=pred2, policy=MinCostPolicy(deadline_ms=4500.0))
    res2 = PlacementRuntime(eng2, TwinBackend(twin, seed=13)).serve(tasks)

    assert [r.target for r in res1.records] == [r.target for r in res2.records]
    assert res1.total_actual_cost == res2.total_actual_cost
    assert res1.avg_actual_latency_ms == res2.avg_actual_latency_ms


def test_simulation_wrapper_supports_fleet_engines(fd_setup):
    """The deprecated wrapper provisions one twin executor per fleet device
    (full speed) instead of crashing on unknown device names."""
    twin, models = fd_setup
    tasks = twin.workload(40, seed=14)
    eng = DecisionEngine(
        predictor=build_fleet_predictor(models, 3, configs=CONFIGS),
        policy=MinLatencyPolicy(c_max=0.0, alpha=0.0))
    res = Simulation(twin, eng, seed=13).run(tasks)
    assert res.n_edge == 40
    assert res.configs_used() <= {"edge0", "edge1", "edge2"}


# ------------------------------------------------------ per-device summaries
def test_device_summaries(ir_setup):
    twin, models = ir_setup
    tasks = BurstyWorkload(rate_per_s=4.0, size_sampler=twin.sample_input,
                           burst_multiplier=6.0, seed=9).generate(600)
    res = _fleet_runtime(twin, models, c_max=0.0, alpha=0.0).serve(tasks)
    assert res.n_edge == res.n  # zero budget: everything on the fleet
    summaries = res.device_summaries()
    assert set(summaries) == set(NAMES)
    assert sum(s.n_tasks for s in summaries.values()) == res.n
    for s in summaries.values():
        assert s.n_tasks > 0
        assert 0.0 < s.utilization <= 1.0
        assert s.queue_wait_p99_ms >= s.queue_wait_p50_ms >= 0.0
        assert s.queue_wait_mean_ms >= 0.0
    assert res.makespan_ms > 0
    table = res.device_table()
    for name in NAMES:
        assert name in table


# ------------------------------------------------------ GBRT kernel routing
def test_gbrt_kernel_batched_path_matches_numpy(fd_setup, monkeypatch):
    """``predict_batch`` routed through the Pallas GBRT kernel must agree
    with the numpy tree walk (f32 kernel → small tolerance)."""
    jax = pytest.importorskip("jax")  # noqa: F841
    twin, models = fd_setup
    tasks = twin.workload(64, seed=10)

    pred_np = build_predictor(models, configs=CONFIGS)
    monkeypatch.setattr(predictor_mod, "GBRT_KERNEL_MODE", "off")
    batch_np = pred_np.predict_batch(tasks)

    pred_k = build_predictor(models, configs=CONFIGS)
    monkeypatch.setattr(predictor_mod, "GBRT_KERNEL_MODE", "force")
    batch_k = pred_k.predict_batch(tasks)

    for name in batch_np.cloud:
        np.testing.assert_allclose(batch_k.cloud[name].warm["comp"],
                                   batch_np.cloud[name].warm["comp"],
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(batch_k.cloud[name].warm_latency,
                                   batch_np.cloud[name].warm_latency,
                                   rtol=1e-4, atol=1e-3)


def test_gbrt_kernel_auto_mode_uses_numpy_on_cpu(fd_setup, monkeypatch):
    """On a non-TPU backend, auto mode must fall back to the numpy walk and
    preserve exact scalar/batch decision parity."""
    twin, models = fd_setup
    tasks = twin.workload(40, seed=11)
    monkeypatch.setattr(predictor_mod, "GBRT_KERNEL_MODE", "auto")
    monkeypatch.setattr(predictor_mod, "GBRT_KERNEL_MIN_BATCH", 1)
    pred = build_predictor(models, configs=CONFIGS)
    batch = pred.predict_batch(tasks)
    pred2 = build_predictor(models, configs=CONFIGS)
    for i, task in enumerate(tasks):
        per = pred2.predict(task, task.arrival_ms)
        bat = pred.predict_at(batch, i, task.arrival_ms)
        for name in per:
            np.testing.assert_allclose(bat[name].latency_ms,
                                       per[name].latency_ms, rtol=1e-12)


# ------------------------------------------- columnar core (ISSUE 3)
import repro.core.decision as decision_mod
from repro.core.decision import DecisionBatch, MinLatencyPolicy as _MLP
from repro.core.records import RecordBatch


def _columnar_vs_step(twin, models, tasks, policy_factory, *, seed=11,
                      balancer_factory=None, fleet=True):
    """Serve the same workload batched-columnar and stepwise; return both
    results plus the columnar engine (for stats) after asserting the full
    decision stream is bit-identical."""
    def run(batched):
        if fleet:
            pred = build_fleet_predictor(models, dict(FLEET), configs=CONFIGS)
            backend = TwinBackend(twin, seed=seed, edge_names=NAMES,
                                  edge_speed=FLEET)
        else:
            pred = build_predictor(models, configs=CONFIGS)
            backend = TwinBackend(twin, seed=seed)
        kwargs = {}
        if balancer_factory is not None:
            kwargs["balancer"] = balancer_factory()
        eng = DecisionEngine(predictor=pred, policy=policy_factory(), **kwargs)
        return PlacementRuntime(eng, backend).serve(tasks, batched=batched), eng

    (a, eng_a), (b, eng_b) = run(True), run(False)
    assert isinstance(a.records, RecordBatch)
    assert [r.target for r in a.records] == [r.target for r in b.records]
    assert [r.allowed_cost for r in a.records] == \
        [r.allowed_cost for r in b.records]
    assert [r.predicted_cold for r in a.records] == \
        [r.predicted_cold for r in b.records]
    assert [r.feasible for r in a.records] == [r.feasible for r in b.records]
    assert [r.queue_wait_ms for r in a.records] == \
        [r.queue_wait_ms for r in b.records]
    assert a.total_actual_cost == b.total_actual_cost
    assert a.avg_actual_latency_ms == b.avg_actual_latency_ms
    return a, b, eng_a


def test_columnar_surplus_crosses_budget_mid_chunk(ir_setup, monkeypatch):
    """Alg. 1's bank: with a sub-cloud budget and α > 0 the surplus accrues
    until a cloud config becomes affordable mid-chunk — the speculated
    frozen-allowed choice is wrong there and must be repaired, bit-exactly."""
    monkeypatch.setattr(decision_mod, "COLUMNAR_CHUNK", 64)
    twin, models = ir_setup
    tasks = twin.workload(400, seed=21)
    # c_max below every cloud cost; the bank alone opens the cloud door
    a, _, eng = _columnar_vs_step(twin, models, tasks,
                                  lambda: _MLP(c_max=4e-6, alpha=0.9))
    assert eng.columnar_stats is not None
    assert eng.columnar_stats["repairs"] + eng.columnar_stats["walked"] > 0, \
        "scenario must actually exercise the repair/fallback path"
    used = {r.target for r in a.records}
    assert any(t not in FLEET for t in used), "cloud must eventually open"
    assert any(t in FLEET for t in used)


def test_columnar_cil_flips_warm_to_cold_mid_chunk(fd_setup, monkeypatch):
    """A short container lifetime expires warm state *between* arrivals inside
    one chunk: the speculated warm latency flips cold and must be repaired."""
    monkeypatch.setattr(decision_mod, "COLUMNAR_CHUNK", 256)
    twin, models = fd_setup
    from repro.core.cil import ContainerInfoList
    tasks = twin.workload(300, seed=22)

    def run(batched):
        pred = build_fleet_predictor(models, dict(FLEET), configs=CONFIGS)
        # lifetime shorter than typical arrival gaps: warm windows keep closing
        pred.cil = ContainerInfoList(t_idl_ms=400.0)
        eng = DecisionEngine(predictor=pred,
                             policy=MinLatencyPolicy(c_max=8e-5, alpha=0.02))
        backend = TwinBackend(twin, seed=23, edge_names=NAMES, edge_speed=FLEET)
        res = PlacementRuntime(eng, backend).serve(tasks, batched=batched)
        return res, eng

    (a, eng), (b, _) = run(True), run(False)
    assert [r.target for r in a.records] == [r.target for r in b.records]
    assert [r.predicted_cold for r in a.records] == \
        [r.predicted_cold for r in b.records]
    assert a.total_actual_cost == b.total_actual_cost
    colds = [r.predicted_cold for r in a.records if r.target not in FLEET]
    assert True in colds and False in colds, \
        "the CIL must actually flip warm/cold inside the workload"


def test_columnar_bursty_fleet_forces_repair_segments(ir_setup, monkeypatch):
    """Bursty arrivals on an edge-first budget: queue growth keeps flipping
    the edge/cloud choice, forcing many repair segments (and, when they get
    dense, the scalar-on-arrays fallback) — all bit-identical to step."""
    monkeypatch.setattr(decision_mod, "COLUMNAR_CHUNK", 128)
    twin, models = ir_setup
    tasks = BurstyWorkload(rate_per_s=4.0, size_sampler=twin.sample_input,
                           burst_multiplier=8.0, mean_quiet_s=10.0,
                           mean_burst_s=6.0, seed=31).generate(1500)
    a, _, eng = _columnar_vs_step(twin, models, tasks,
                                  lambda: _MLP(c_max=6e-6, alpha=0.05))
    stats = eng.columnar_stats
    assert stats["repairs"] >= 5, f"expected many repair segments, got {stats}"
    used = {r.target for r in a.records}
    assert any(t in FLEET for t in used) and any(t not in FLEET for t in used)


def test_columnar_round_robin_and_random_balancers(ir_setup):
    """Wait-independent balancers ride the columnar path via precomputed
    nomination sequences — including their consumed state (RR index, RNG)."""
    twin, models = ir_setup
    tasks = twin.workload(250, seed=24)
    for factory in (RoundRobinBalancer, lambda: RandomBalancer(seed=5)):
        a, b, eng = _columnar_vs_step(twin, models, tasks,
                                      lambda: _MLP(c_max=2e-6, alpha=0.01),
                                      balancer_factory=factory)
        assert isinstance(eng.columnar_stats, dict)
        devs = {r.target for r in a.records if r.target in FLEET}
        assert len(devs) >= 2  # the balancer actually spread the load


def test_columnar_single_edge_and_mincost(fd_setup):
    """Fleet-of-one + MinCost: the columnar kernels cover the paper's exact
    configuration (including the infeasible→edge-queue fallback rows)."""
    twin, models = fd_setup
    tasks = twin.workload(300, seed=25)
    a, _, eng = _columnar_vs_step(twin, models, tasks,
                                  lambda: MinCostPolicy(deadline_ms=2500.0),
                                  fleet=False)
    assert eng.columnar_stats is not None
    assert False in [r.feasible for r in a.records], \
        "deadline must actually be violated somewhere"


def test_columnar_falls_back_for_custom_policy(fd_setup):
    """Hedged (or any non-paper) policy must take the per-task walk — and
    place_many then returns plain PlacementDecision objects."""
    twin, models = fd_setup
    tasks = twin.workload(50, seed=26)
    pred = build_fleet_predictor(models, dict(FLEET), configs=CONFIGS)
    eng = DecisionEngine(
        predictor=pred,
        policy=HedgedPolicy(MinLatencyPolicy(c_max=8e-5, alpha=0.0),
                            hedge_threshold_ms=1500.0))
    decisions = eng.place_many(tasks)
    assert isinstance(decisions, list)
    assert not isinstance(decisions, DecisionBatch)


def test_columnar_decision_batch_views_and_memory_optin(ir_setup):
    """DecisionBatch lazily materializes PlacementDecision views; decision
    recording stays opt-in on the batched path too."""
    twin, models = ir_setup
    tasks = twin.workload(60, seed=27)
    pred = build_fleet_predictor(models, dict(FLEET), configs=CONFIGS)
    eng = DecisionEngine(predictor=pred, policy=_MLP(c_max=2e-6, alpha=0.0))
    batch = eng.place_many(tasks)
    assert isinstance(batch, DecisionBatch)
    assert eng.decisions == []  # opt-in recording: nothing accumulated
    d0 = batch[0]
    assert d0.task_idx == 0 and d0.target in batch.names
    assert d0.prediction.components  # lazy component dict materializes
    assert len(batch.target_list()) == len(tasks) == len(batch)

    eng_rec = DecisionEngine(predictor=build_fleet_predictor(
        models, dict(FLEET), configs=CONFIGS),
        policy=_MLP(c_max=2e-6, alpha=0.0), record_decisions=True)
    eng_rec.place_many(tasks)
    assert len(eng_rec.decisions) == len(tasks)


def test_columnar_unsorted_arrivals_fall_back_to_walk(fd_setup):
    """Out-of-order arrival times must take the per-task walk: the walk's
    per-task cil.reap(now) at a far-future task permanently drops expired
    containers before earlier-timed tasks are decided, which the columnar
    snapshot cannot replicate. Parity is with the step path, as always."""
    from repro.core.cil import ContainerInfoList
    twin, models = fd_setup
    tasks = twin.workload(60, seed=28)
    # interleave far-future arrivals so time jumps back and forth
    for i, t in enumerate(tasks):
        if i % 5 == 2:
            t.arrival_ms += 1e6

    def run(batched):
        pred = build_fleet_predictor(models, dict(FLEET), configs=CONFIGS)
        pred.cil = ContainerInfoList(t_idl_ms=5000.0)
        eng = DecisionEngine(predictor=pred,
                             policy=MinLatencyPolicy(c_max=8e-5, alpha=0.02))
        backend = TwinBackend(twin, seed=29, edge_names=NAMES, edge_speed=FLEET)
        res = PlacementRuntime(eng, backend).serve(tasks, batched=batched)
        return res, eng

    (a, eng), (b, _) = run(True), run(False)
    assert eng.columnar_stats is None  # columnar declined: walk was used
    assert [r.target for r in a.records] == [r.target for r in b.records]
    assert [r.predicted_cold for r in a.records] == \
        [r.predicted_cold for r in b.records]
    assert a.total_actual_cost == b.total_actual_cost
