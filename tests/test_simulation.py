"""End-to-end paper reproduction tests: twin → fit → simulate → metrics."""

from __future__ import annotations

import numpy as np
import pytest

from repro.core.apps import APPS, AWSTwin, collect_measurements
from repro.core.decision import DecisionEngine, MinCostPolicy, MinLatencyPolicy
from repro.core.fit import build_predictor, fit_app, fit_models
from repro.core.simulator import Simulation

# small-but-meaningful sizes for CI speed
N_INPUTS = 150
N_TASKS = 200
CONFIGS = (1280, 1536, 1792)


@pytest.fixture(scope="module")
def fd_setup():
    twin, models = fit_app("FD", seed=0, n_inputs=N_INPUTS, configs=CONFIGS)
    return twin, models


def test_model_fit_quality(fd_setup):
    """Paper Table II: end-to-end MAPE below ~16% for FD; edge more accurate."""
    _, models = fd_setup
    assert models.cloud_e2e_mape < 20.0
    assert models.edge_e2e_mape < 10.0
    assert models.edge_e2e_mape < models.cloud_e2e_mape


def test_cold_start_slower_than_warm(fd_setup):
    _, models = fd_setup
    assert models.start_cold.mean > 3 * models.start_warm.mean


def test_min_latency_simulation(fd_setup):
    twin, models = fd_setup
    tasks = twin.workload(N_TASKS, seed=3)
    pred = build_predictor(models, configs=CONFIGS)
    eng = DecisionEngine(predictor=pred,
                         policy=MinLatencyPolicy(c_max=2.97e-5, alpha=0.02))
    res = Simulation(twin, eng, seed=5).run(tasks)
    assert res.n == N_TASKS
    # paper Table IV: latency prediction error is small; budget respected
    assert res.latency_error_pct < 15.0
    assert res.total_actual_cost <= 2.97e-5 * N_TASKS  # aggregate budget holds
    assert res.pct_budget_used < 100.0


def test_min_cost_simulation(fd_setup):
    twin, models = fd_setup
    tasks = twin.workload(N_TASKS, seed=4)
    pred = build_predictor(models, configs=CONFIGS)
    eng = DecisionEngine(predictor=pred, policy=MinCostPolicy(deadline_ms=4500))
    res = Simulation(twin, eng, seed=6).run(tasks)
    # paper Table III: few deadline violations, cost prediction close
    assert res.pct_deadline_violated < 10.0
    assert res.cost_error_pct < 15.0


def test_simulation_deterministic(fd_setup):
    twin, models = fd_setup
    tasks = twin.workload(60, seed=9)

    def run():
        pred = build_predictor(models, configs=CONFIGS)
        eng = DecisionEngine(predictor=pred,
                             policy=MinLatencyPolicy(c_max=2.97e-5, alpha=0.02))
        return Simulation(twin, eng, seed=11).run(tasks)

    a, b = run(), run()
    assert a.total_actual_cost == b.total_actual_cost
    assert [r.target for r in a.records] == [r.target for r in b.records]


def test_edge_only_queue_collapse(fd_setup):
    """Paper Sec. VI-B: edge-only execution collapses under queueing (the
    ~3-orders-of-magnitude latency gap vs. dynamic placement)."""
    twin, models = fd_setup
    tasks = twin.workload(N_TASKS, seed=7)
    # placement framework
    pred = build_predictor(models, configs=CONFIGS)
    eng = DecisionEngine(predictor=pred,
                         policy=MinLatencyPolicy(c_max=2.97e-5, alpha=0.02))
    res = Simulation(twin, eng, seed=8).run(tasks)
    # edge-only: min-latency with zero budget and alpha=0 forces the edge
    pred0 = build_predictor(models, configs=CONFIGS)
    eng0 = DecisionEngine(predictor=pred0,
                          policy=MinLatencyPolicy(c_max=0.0, alpha=0.0))
    res0 = Simulation(twin, eng0, seed=8).run(tasks)
    assert res0.n_edge == N_TASKS
    assert res0.avg_actual_latency_ms > 50 * res.avg_actual_latency_ms


def test_quantile_prediction_reduces_violations():
    """Beyond-paper: P95 predictors trade cost for fewer deadline violations.

    Uses STT (the paper's highest-variance app, Table III: ~6-8% violations)
    with its paper deadline δ = 5.5 s — with a mean predictor some violations
    occur; the quantile predictor must not increase them. (At overly tight
    deadlines quantile inflation empties the feasible set and everything
    falls back to the edge queue — the deadline must leave P95 headroom.)
    """
    twin, models = fit_app("STT", seed=0, n_inputs=150,
                           configs=(768, 1152, 1280, 1664))
    tasks = twin.workload(N_TASKS, seed=12)

    def run(quantile):
        pred = build_predictor(models, configs=(768, 1152, 1280, 1664),
                               quantile=quantile)
        eng = DecisionEngine(predictor=pred, policy=MinCostPolicy(5500.0))
        return Simulation(twin, eng, seed=13).run(tasks)

    mean_res = run(None)
    q_res = run(0.95)
    assert q_res.pct_deadline_violated <= mean_res.pct_deadline_violated + 1e-9


@pytest.mark.parametrize("app", sorted(APPS))
def test_twin_statistics_match_table1(app):
    """The AWS twin's component means reproduce paper Table I (±15%)."""
    spec = APPS[app]
    twin = AWSTwin(spec=spec, seed=1)
    rng = np.random.default_rng(2)
    warm = np.mean([twin.start_ms(False, rng) for _ in range(300)])
    cold = np.mean([twin.start_ms(True, rng) for _ in range(300)])
    store = np.mean([twin.store_cloud_ms(rng) for _ in range(300)])
    table1 = {"IR": (162, 741, 549), "FD": (163, 1500, 584),
              "STT": (145, 1404, 533)}
    w, c, s = table1[app]
    assert abs(warm - w) / w < 0.15
    assert abs(cold - c) / c < 0.15
    assert abs(store - s) / s < 0.15


def test_collect_measurements_shapes():
    twin = AWSTwin(spec=APPS["IR"], seed=0)
    meas = collect_measurements(twin, n_inputs=20, configs=(640, 1792), n_cold=5)
    assert meas.sizes.shape == (40,)  # 20 inputs × 2 configs
    assert meas.start_cold.shape == (10,)
    assert meas.edge_sizes.shape == (20,)
    models = fit_models(meas)
    assert np.isfinite(models.cloud_e2e_mape)
