"""Training substrate: checkpoint/restart fault tolerance, compression, data."""

from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.distributed.compression import (
    CompressionConfig,
    compress_decompress,
    compressed_bytes,
    init_error_state,
)
from repro.modeling.registry import build_model
from repro.training import checkpoint as ckpt
from repro.training.data import TokenPipeline, DataConfig, make_pipeline
from repro.training.optimizer import OptimizerConfig, lr_schedule
from repro.training.train_loop import (
    FailureInjector,
    LoopConfig,
    SimulatedFailure,
    run_with_restarts,
    train,
)


def _tiny_setup(tmp_path=None, steps=8, ckpt_every=4, compression="none"):
    cfg = smoke_config("llama3.2-1b").with_updates(
        n_layers=2, d_model=32, d_ff=64, vocab=64, n_heads=2, n_kv_heads=2,
        head_dim=16)
    model = build_model(cfg)
    pipeline = make_pipeline(cfg, seq_len=16, global_batch=2, seed=0)
    loop = LoopConfig(steps=steps, log_every=100, ckpt_every=ckpt_every,
                      ckpt_dir=str(tmp_path) if tmp_path else None,
                      compression=CompressionConfig(scheme=compression))
    opt = OptimizerConfig(peak_lr=1e-3, warmup_steps=2, decay_steps=steps)
    return model, pipeline, loop, opt


# ------------------------------------------------------------- checkpointing
def test_checkpoint_roundtrip_and_keep_k(tmp_path):
    state = {"a": {"b": np.arange(6).reshape(2, 3).astype(np.float32)},
             "c": np.float32(3.5)}
    for step in (1, 2, 3, 4):
        ckpt.save_checkpoint(str(tmp_path), step, state, keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 4
    restored_step, tree = ckpt.restore_latest(str(tmp_path))
    assert restored_step == 4
    np.testing.assert_array_equal(tree["a"]["b"], state["a"]["b"])
    # keep=2 pruned old checkpoints
    import os
    dirs = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert len(dirs) == 2


def test_restart_matches_uninterrupted_run(tmp_path):
    """Kill at step 6, restart from checkpoint → same final loss trajectory."""
    model, pipeline, loop, opt = _tiny_setup(tmp_path, steps=10, ckpt_every=2)

    # uninterrupted reference
    ref = train(model, pipeline,
                LoopConfig(steps=10, log_every=100, ckpt_every=1000,
                           ckpt_dir=None),
                opt, key=jax.random.key(0))

    injector = FailureInjector(fail_at=6)
    res = run_with_restarts(model, pipeline, loop, opt, key=jax.random.key(0),
                            injector=injector)
    assert res.restarts == 1
    assert res.final_step == 10
    # post-restart losses must match the uninterrupted run bit-for-bit-ish
    np.testing.assert_allclose(res.losses[-3:], ref.losses[-3:], rtol=1e-5)


def test_failure_without_checkpoint_raises():
    model, pipeline, loop, opt = _tiny_setup(None, steps=10)
    injector = FailureInjector(fail_at=3)
    with pytest.raises(SimulatedFailure):
        run_with_restarts(model, pipeline, loop, opt, injector=injector,
                          max_restarts=0)


# ------------------------------------------------------------------- data
def test_pipeline_deterministic_and_restartable():
    pipe = TokenPipeline(DataConfig(seq_len=16, global_batch=4, vocab=100, seed=3))
    b1 = pipe.batch(7)
    b2 = TokenPipeline(DataConfig(seq_len=16, global_batch=4, vocab=100, seed=3)).batch(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # host sharding partitions the global batch
    h0 = pipe.host_batch(7, 0, 2)
    h1 = pipe.host_batch(7, 1, 2)
    np.testing.assert_array_equal(np.concatenate([h0["tokens"], h1["tokens"]]),
                                  b1["tokens"])


def test_training_loss_decreases():
    model, pipeline, loop, opt = _tiny_setup(None, steps=30)
    res = train(model, pipeline, loop, opt, key=jax.random.key(1))
    assert np.mean(res.losses[-5:]) < np.mean(res.losses[:5])


# ------------------------------------------------------------- compression
@pytest.mark.parametrize("scheme", ["topk", "int8"])
def test_compression_error_feedback_accumulates(scheme, rng):
    grads = {"w": jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)}
    err = init_error_state(grads)
    cfg = CompressionConfig(scheme=scheme, topk_frac=0.1)
    out, new_err = compress_decompress(grads, err, cfg, step=0)
    # error feedback: decompressed + error == corrected gradient exactly
    np.testing.assert_allclose(
        np.asarray(out["w"]) + np.asarray(new_err["w"]),
        np.asarray(grads["w"]), rtol=1e-5, atol=1e-6)


def test_topk_full_fraction_is_identity(rng):
    grads = {"w": jnp.asarray(rng.normal(size=(32,)), jnp.float32)}
    cfg = CompressionConfig(scheme="topk", topk_frac=1.0)
    out, new_err = compress_decompress(grads, init_error_state(grads), cfg)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(grads["w"]),
                               rtol=1e-6)
    assert float(jnp.max(jnp.abs(new_err["w"]))) < 1e-6


def test_compressed_bytes_accounting():
    params = {"w": jnp.zeros((1000,))}
    none = compressed_bytes(params, CompressionConfig(scheme="none"))
    topk = compressed_bytes(params, CompressionConfig(scheme="topk", topk_frac=0.05))
    int8 = compressed_bytes(params, CompressionConfig(scheme="int8"))
    assert none == 4000
    assert topk == 50 * 8
    assert int8 == 1004
    assert topk < int8 < none


def test_train_with_compression_runs():
    model, pipeline, loop, opt = _tiny_setup(None, steps=6, compression="int8")
    res = train(model, pipeline, loop, opt, key=jax.random.key(2))
    assert len(res.losses) == 6
    assert np.all(np.isfinite(res.losses))


# ---------------------------------------------------------------- schedule
def test_lr_schedule_shape():
    cfg = OptimizerConfig(peak_lr=1.0, warmup_steps=10, decay_steps=100,
                          min_lr_frac=0.1)
    assert float(lr_schedule(cfg, jnp.asarray(0))) == 0.0
    assert float(lr_schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(lr_schedule(cfg, jnp.asarray(100))) == pytest.approx(0.1, abs=1e-3)


# ------------------------------------------------------------- elastic
def test_elastic_reshard_roundtrip(tmp_path):
    """Checkpoint → restore → reshard onto the host mesh (1 device)."""
    from repro.distributed.elastic import elastic_restore
    from repro.launch.mesh import make_host_mesh

    model, pipeline, loop, opt = _tiny_setup(tmp_path, steps=4, ckpt_every=2)
    train(model, pipeline, loop, opt, key=jax.random.key(0))
    mesh = make_host_mesh()
    cfg = smoke_config("llama3.2-1b").with_updates(
        n_layers=2, d_model=32, d_ff=64, vocab=64, n_heads=2, n_kv_heads=2,
        head_dim=16)
    out = elastic_restore(str(tmp_path), model, cfg, mesh)
    assert out is not None
    step, params, state = out
    assert step == 4
    for k, v in params.items():
        assert hasattr(v, "sharding")
