"""Trace format + replay (ISSUE 6): ingestion, bit-exact replay, capture.

Covers:
- JSONL and NPZ round trips are bit-exact (JSONL via shortest-repr floats);
  ``load`` dispatches on extension and rejects unknown ones;
- malformed traces are rejected at ingestion with the offending record named
  (unsorted arrivals, NaN/negative sizes, bad app codes, mixed latency
  columns, wrong schema/version) — never silently degraded;
- ``TraceWorkload`` replay through ``serve_stream`` is bit-identical PER
  RECORD to serving the equivalent in-memory task list, at chunk sizes from
  1 upward;
- capture → replay round-trips exactly, for kept-task runs and for
  constant-memory streams with ``keep_inputs=True``; dropped inputs raise
  the actionable error;
- multi-app: ``split_by_app``/``merge`` invert each other; ``trace_shards``
  replay ≡ filtering the trace per app up front; ``capture_sharded`` and
  ``ShardedResult.merged_records`` agree on global arrival order.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core.decision import DecisionEngine, MinLatencyPolicy
from repro.core.fit import build_fleet_predictor, fit_app
from repro.core.multiapp import serve_sharded
from repro.core.runtime import PlacementRuntime, TwinBackend
from repro.core.workload import BurstyWorkload, PoissonWorkload, first_disorder
from repro.trace import (
    Trace,
    TraceError,
    TraceWorkload,
    capture,
    capture_sharded,
    load,
    merge,
    trace_shards,
)

CONFIGS = (1280, 1536, 1792)
FLEET = {"edge0": 1.0, "edge1": 1.0, "edge2": 0.6}
NAMES = tuple(FLEET)

RECORD_COLS = ("predicted_latency_ms", "predicted_cost", "actual_latency_ms",
               "actual_cost", "allowed_cost", "completion_ms", "queue_wait_ms",
               "exec_ms", "hedge_exec_ms", "predicted_cold", "actual_cold",
               "feasible", "hedged")


@pytest.fixture(scope="module")
def ir_setup():
    return fit_app("IR", seed=0, n_inputs=120, configs=CONFIGS)


@pytest.fixture(scope="module")
def stt_setup():
    return fit_app("STT", seed=0, n_inputs=120, configs=CONFIGS)


def _runtime(twin, models, c_max=6e-6, alpha=0.05, seed=11):
    pred = build_fleet_predictor(models, dict(FLEET), configs=CONFIGS)
    eng = DecisionEngine(predictor=pred,
                         policy=MinLatencyPolicy(c_max=c_max, alpha=alpha))
    backend = TwinBackend(twin, seed=seed, edge_names=NAMES, edge_speed=FLEET)
    return PlacementRuntime(eng, backend)


def _bursty_trace(twin, n, seed=31, app="IR"):
    tasks = BurstyWorkload(rate_per_s=4.0, size_sampler=twin.sample_input,
                           burst_multiplier=8.0, mean_quiet_s=10.0,
                           mean_burst_s=6.0, seed=seed).generate(n)
    return tasks, Trace.from_tasks(tasks, app=app)


def assert_records_equal(a, b):
    assert len(a) == len(b)
    assert list(a.targets) == list(b.targets)
    for col in RECORD_COLS:
        assert np.array_equal(getattr(a, col), getattr(b, col)), col
    assert np.array_equal(a.arrival_ms, b.arrival_ms)


def _toy_trace(n=50, seed=3, apps=("IR",)):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, len(apps), size=n)
    return Trace.from_arrays(
        arrival_ms=np.cumsum(rng.exponential(250.0, size=n)),
        size=rng.uniform(1e4, 1e6, size=n),
        bytes=rng.uniform(1e3, 1e5, size=n),
        app_codes=codes, app_names=apps,
        observed_latency_ms=rng.uniform(10.0, 5e4, size=n),
        meta={"source": "toy"})


# ------------------------------------------------------------ format round trips
def test_jsonl_and_npz_round_trips_bit_exact(tmp_path):
    t = _toy_trace(apps=("IR", "STT"))
    pj, pn = tmp_path / "t.jsonl", tmp_path / "t.npz"
    t.save(pj)
    t.save(pn)
    for p in (pj, pn):
        back = load(p)
        assert back.equal(t)
        assert back.app_names == t.app_names
        assert back.meta == {"source": "toy"}
        # bit-exact, not approximately equal
        assert np.array_equal(back.arrival_ms, t.arrival_ms)
        assert np.array_equal(back.observed_latency_ms, t.observed_latency_ms)


def test_round_trip_without_observed_latency(tmp_path):
    t = _toy_trace()
    t = Trace.from_arrays(t.arrival_ms, t.size, t.bytes, t.app_codes,
                          t.app_names)
    assert t.observed_latency_ms is None
    for name in ("a.jsonl", "a.npz"):
        t.save(tmp_path / name)
        assert load(tmp_path / name).equal(t)


def test_load_save_reject_unknown_extension(tmp_path):
    t = _toy_trace()
    with pytest.raises(TraceError, match="cannot infer trace format"):
        t.save(tmp_path / "t.csv")
    with pytest.raises(TraceError, match="cannot infer trace format"):
        load(tmp_path / "t.csv")


def test_jsonl_rejects_wrong_header_and_bad_rows(tmp_path):
    p = tmp_path / "bad.jsonl"
    p.write_text('{"not": "a trace"}\n')
    with pytest.raises(TraceError, match="header"):
        load(p)
    p.write_text('{"schema": "repro.trace", "version": 1, "apps": ["IR"]}\n'
                 '{"t": 1.0, "size": 5.0, "bytes": 2.0}\n')
    with pytest.raises(TraceError, match="line 2.*'app'"):
        load(p)
    # all-or-none observed latency, offending line named
    p.write_text('{"schema": "repro.trace", "version": 1, "apps": ["IR"]}\n'
                 '{"t": 1.0, "app": 0, "size": 5.0, "bytes": 2.0, "lat": 9.0}\n'
                 '{"t": 2.0, "app": 0, "size": 5.0, "bytes": 2.0}\n')
    with pytest.raises(TraceError, match="line 3.*all-or-none"):
        load(p)


def test_version_gate(tmp_path):
    p = tmp_path / "new.jsonl"
    p.write_text('{"schema": "repro.trace", "version": 99, "apps": ["IR"]}\n')
    with pytest.raises(TraceError, match="version 99"):
        load(p)


# ------------------------------------------------------------------ validation
def test_unsorted_trace_rejected_with_offending_index():
    arr = [0.0, 10.0, 5.0, 20.0]
    with pytest.raises(TraceError) as e:
        Trace.from_arrays(arr, [1, 1, 1, 1], [1, 1, 1, 1])
    msg = str(e.value)
    assert "record 2" in msg and "10.0" in msg and "5.0" in msg
    # the error names the same index the serve-path detector computes
    assert first_disorder(arr) == 2
    # rather than silently dropping to the per-task walk
    assert "per-task walk" in msg


def test_nan_and_negative_inputs_rejected_with_index():
    with pytest.raises(TraceError, match="record 1: NaN size"):
        Trace.from_arrays([0.0, 1.0], [1.0, float("nan")], [1.0, 1.0])
    with pytest.raises(TraceError, match="record 0: negative bytes"):
        Trace.from_arrays([0.0, 1.0], [1.0, 1.0], [-3.0, 1.0])
    with pytest.raises(TraceError, match="non-finite arrival"):
        Trace.from_arrays([0.0, float("inf")], [1.0, 1.0], [1.0, 1.0])


def test_app_code_and_name_validation():
    with pytest.raises(TraceError, match="record 1: app code 7"):
        Trace.from_arrays([0.0, 1.0], [1, 1], [1, 1], app_codes=[0, 7],
                          app_names=("IR",))
    with pytest.raises(TraceError, match="duplicate app names"):
        Trace.from_arrays([0.0], [1], [1], app_names=("IR", "IR"))
    t = _toy_trace()
    with pytest.raises(TraceError, match="unknown app 'FD'.*IR"):
        t.for_app("FD")


def test_column_length_mismatch_rejected():
    with pytest.raises(TraceError, match="'size' has 1 records but"):
        Trace.from_arrays([0.0, 1.0], [1.0], [1.0, 1.0])


# --------------------------------------------------------------- replay parity
def test_trace_replay_bit_identical_to_in_memory(ir_setup):
    """The tentpole guarantee: a ``TraceWorkload`` streamed through
    ``serve_stream`` produces per-record identical results to serving the
    in-memory task list it was recorded from — at every chunk size."""
    twin, models = ir_setup
    tasks, trace = _bursty_trace(twin, 700)
    ref = _runtime(twin, models).serve(tasks, batched=True)
    tw = TraceWorkload(trace)
    for chunk_size in (1, 53, 256, 700, 5000):
        rt = _runtime(twin, models)
        res = rt.serve_stream(tw.chunks(chunk_size=chunk_size))
        assert_records_equal(res.records, ref.records)
    # the whole-trace TaskChunk spelling, sliced by serve_stream itself
    res = _runtime(twin, models).serve_stream(tw.task_chunk(), chunk_size=97)
    assert_records_equal(res.records, ref.records)


def test_trace_replay_after_disk_round_trip(ir_setup, tmp_path):
    twin, models = ir_setup
    tasks, trace = _bursty_trace(twin, 300, seed=5)
    ref = _runtime(twin, models).serve(tasks, batched=True)
    for name in ("t.jsonl", "t.npz"):
        trace.save(tmp_path / name)
        res = _runtime(twin, models).serve_stream(
            TraceWorkload(load(tmp_path / name)).chunks(chunk_size=64))
        assert_records_equal(res.records, ref.records)


def test_trace_workload_generate_matches_chunks(ir_setup):
    twin, models = ir_setup
    _, trace = _bursty_trace(twin, 200, seed=8)
    tw = TraceWorkload(trace)
    gen = tw.generate()
    assert len(gen) == 200
    flat = [t for c in tw.chunks(chunk_size=17) for t in c]
    for a, b in zip(gen, flat):
        assert (a.arrival_ms, a.size, a.bytes) == (b.arrival_ms, b.size, b.bytes)
    with pytest.raises(TraceError, match="only 200 records"):
        tw.generate(201)


# ------------------------------------------------------------------- capture
def test_capture_replay_round_trip(ir_setup):
    twin, models = ir_setup
    tasks, _ = _bursty_trace(twin, 400, seed=13)
    ref = _runtime(twin, models).serve(tasks, batched=True)
    t = capture(ref, app="IR")
    # captured inputs are the served inputs, observed latency the actual one
    assert np.array_equal(t.observed_latency_ms, ref.records.actual_latency_ms)
    res = _runtime(twin, models).serve_stream(
        TraceWorkload(t).chunks(chunk_size=71), keep_inputs=True)
    assert_records_equal(res.records, ref.records)
    # and capture of the replay equals the original capture
    assert capture(res, app="IR").equal(t)


def test_capture_from_constant_memory_stream(ir_setup):
    twin, models = ir_setup
    tasks, trace = _bursty_trace(twin, 300, seed=21)
    ref = _runtime(twin, models).serve(tasks, batched=True)
    rt = _runtime(twin, models)
    res = rt.serve_stream(TraceWorkload(trace).chunks(chunk_size=64),
                          keep_tasks=False, keep_inputs=True)
    assert res.records.tasks == []  # genuinely constant-memory
    t = capture(res, app="IR")
    assert t.equal(capture(ref, app="IR"))

    # without keep_inputs the capture fails with the actionable fix
    rt2 = _runtime(twin, models)
    res2 = rt2.serve_stream(TraceWorkload(trace).chunks(chunk_size=64),
                            keep_tasks=False)
    with pytest.raises(ValueError, match="keep_inputs=True"):
        capture(res2, app="IR")


# ------------------------------------------------------------------ multi-app
def _multiapp_trace(ir_setup, stt_setup, n_ir=200, n_stt=60):
    ir_twin, _ = ir_setup
    stt_twin, _ = stt_setup
    ir = Trace.from_tasks(
        PoissonWorkload(rate_per_s=4.0, size_sampler=ir_twin.sample_input,
                        seed=3).generate(n_ir), app="IR")
    stt = Trace.from_tasks(
        PoissonWorkload(rate_per_s=0.5, size_sampler=stt_twin.sample_input,
                        seed=4).generate(n_stt), app="STT")
    return merge({"IR": ir, "STT": stt})


def test_merge_split_invert(ir_setup, stt_setup):
    m = _multiapp_trace(ir_setup, stt_setup)
    assert m.app_names == ("IR", "STT")
    assert first_disorder(m.arrival_ms) == -1
    parts = m.split_by_app()
    assert merge(parts).equal(m)
    assert parts["IR"].n + parts["STT"].n == m.n
    with pytest.raises(TraceError, match="single-app"):
        merge({"both": m})


def test_sharded_replay_equals_upfront_filter(ir_setup, stt_setup):
    """Satellite regression: replaying a multi-app trace through
    ``ShardedRuntime`` shards ≡ filtering the trace per app up front and
    serving each filtered trace alone."""
    ir_twin, ir_models = ir_setup
    stt_twin, stt_models = stt_setup
    m = _multiapp_trace(ir_setup, stt_setup)

    shards = trace_shards(
        m, {"IR": _runtime(ir_twin, ir_models),
            "STT": _runtime(stt_twin, stt_models)}, chunk_size=64)
    sharded = serve_sharded(shards, parallel=False)

    for app, twin, models in (("IR", ir_twin, ir_models),
                              ("STT", stt_twin, stt_models)):
        solo = _runtime(twin, models).serve_stream(
            TraceWorkload(m.for_app(app)).chunks(chunk_size=64))
        assert_records_equal(sharded.results[app].records, solo.records)

    # runtime factories for every trace app are mandatory
    with pytest.raises(TraceError, match=r"\['STT'\]"):
        trace_shards(m, {"IR": _runtime(ir_twin, ir_models)})


def test_capture_sharded_round_trip(ir_setup, stt_setup):
    ir_twin, ir_models = ir_setup
    stt_twin, stt_models = stt_setup
    m = _multiapp_trace(ir_setup, stt_setup, n_ir=150, n_stt=40)
    shards = trace_shards(
        m, {"IR": _runtime(ir_twin, ir_models),
            "STT": _runtime(stt_twin, stt_models)},
        chunk_size=64, keep_tasks=True)
    sharded = serve_sharded(shards, parallel=False)

    t = capture_sharded(sharded)
    # inputs survive the capture exactly; only latency is new information
    assert np.array_equal(t.arrival_ms, m.arrival_ms)
    assert np.array_equal(t.size, m.size)
    assert np.array_equal(t.bytes, m.bytes)
    assert np.array_equal(t.app_codes, m.app_codes)
    assert t.observed_latency_ms is not None

    # merged_records orders rows exactly like the captured trace
    rb, codes, names = sharded.merged_records()
    assert names == ("IR", "STT")
    assert np.array_equal(rb.arrival_ms, t.arrival_ms)
    assert np.array_equal(codes, t.app_codes)
    lat_by_arrival = rb.actual_latency_ms
    assert np.array_equal(lat_by_arrival, t.observed_latency_ms)


def test_trace_shards_process_mode(ir_setup):
    """``as_factories=True`` + runtime factories: full process isolation,
    results bit-identical to the sequential replay."""
    twin, models = ir_setup
    _, trace = _bursty_trace(twin, 200, seed=17)
    single = merge({"IR": trace})

    seq = serve_sharded(
        trace_shards(single, {"IR": _make_ir_runtime}, chunk_size=64),
        parallel=False)
    proc = serve_sharded(
        trace_shards(single, {"IR": _make_ir_runtime}, chunk_size=64,
                     as_factories=True),
        parallel=True, use_processes=True)
    assert proc.mode == "process"
    assert_records_equal(seq.results["IR"].records, proc.results["IR"].records)


def _make_ir_runtime():
    """Top-level runtime factory (picklable) for the process-mode test."""
    twin, models = fit_app("IR", seed=0, n_inputs=120, configs=CONFIGS)
    return _runtime(twin, models)


# ---------------------------------------------------------------- misc shapes
def test_prefix_and_duration():
    t = _toy_trace(n=20)
    p = t.prefix(7)
    assert p.n == 7 and np.array_equal(p.arrival_ms, t.arrival_ms[:7])
    assert t.prefix(10_000).n == 20
    assert t.prefix(0).n == 0
    assert t.duration_ms == float(t.arrival_ms[-1] - t.arrival_ms[0])


def test_empty_trace_round_trip(tmp_path):
    t = Trace.from_arrays([], [], [], app_names=("IR",))
    assert t.n == 0 and t.duration_ms == 0.0
    for name in ("e.jsonl", "e.npz"):
        t.save(tmp_path / name)
        assert load(tmp_path / name).equal(t)
