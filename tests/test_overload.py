"""Overload survival: predictive pre-warming + fair-share reclamation (ISSUE 10).

Covers:
- policy construction validation: ``PrewarmPolicy`` / ``ReclamationPolicy``
  knobs, the strictly-decreasing tier-deadline ordering (shared with
  ``AdmissionPolicy``), and the NaN-proofed ``RetryPolicy`` bounds;
- the burst forecaster: chunking-invariance of the scalar fold (the property
  the cross-path schedule-identity contract rests on), MMPP burst detection
  against ``TaskChunk.burst`` ground truth, trigger cooldown;
- ``BurstyWorkload.chunks`` carrying the phase flag columnarly, matching
  ``generate``'s per-task ``meta['burst']`` bit for bit;
- the CIL prewarm encoding: warm exactly over [ready, keepalive_until];
- accounting invariants: every prewarmed container billed exactly once at
  spawn (keep-alive extensions unbilled), kept-in-place preemption rollback
  leaving surplus / horizons / records exactly as the reclamation-off run;
- schedule identity: fixed seed reproduces the identical prewarm / preempt /
  downgrade schedule across runs and across serve / serve_async /
  serve_stream;
- the off/idle parity guarantee: overload armed but never firing is
  bit-identical per record to the plain runtime on every serve path and
  chunking — plus the hypothesis property over random chunkings;
- ``select_victims`` fair-share semantics as a pure function;
- ``downgraded`` as a first-class ``RecordBatch`` column.
"""

from __future__ import annotations

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # gated, not required: the container may not ship it
    HAVE_HYPOTHESIS = False

from repro.core.decision import DecisionEngine, MinCostPolicy, MinLatencyPolicy
from repro.core.faults import (
    AdmissionPolicy,
    FaultError,
    RetryPolicy,
    SLOTier,
)
from repro.core.cil import ContainerInfoList
from repro.core.fit import build_fleet_predictor, fit_app
from repro.core.overload import (
    BurstForecaster,
    OverloadManager,
    PrewarmPolicy,
    ReclamationPolicy,
    select_victims,
)
from repro.core.records import RecordBatch, SimulationResult, TaskRecord
from repro.core.runtime import PlacementRuntime, TwinBackend
from repro.core.workload import BurstyWorkload, TaskInput


def _rec(i, completion_ms, downgraded=False):
    return TaskRecord(task=TaskInput(idx=i, arrival_ms=float(i), size=1.0,
                                     bytes=1.0),
                      target="1792", predicted_latency_ms=1.0,
                      predicted_cost=0.0, actual_latency_ms=1.0,
                      actual_cost=0.0, predicted_cold=False,
                      actual_cold=False, allowed_cost=0.0, feasible=True,
                      completion_ms=completion_ms, downgraded=downgraded)

CONFIGS = (1280, 1536, 1792)
FLEET3 = {"edge0": 1.0, "edge1": 1.0, "edge2": 0.6}

RECORD_COLS = ("actual_latency_ms", "actual_cost", "completion_ms",
               "target_codes", "queue_wait_ms", "exec_ms", "predicted_cost",
               "predicted_latency_ms", "attempts", "failed", "shed",
               "downgraded", "tier")


@pytest.fixture(scope="module")
def fd_setup():
    return fit_app("FD", seed=0, n_inputs=120, configs=CONFIGS)


def _runtime(twin, models, fleet, policy=None, prewarm=None, reclamation=None,
             seed=11):
    pred = build_fleet_predictor(models, dict(fleet), configs=CONFIGS)
    policy = policy or MinLatencyPolicy(c_max=2.97e-5, alpha=0.02)
    eng = DecisionEngine(predictor=pred, policy=policy)
    backend = TwinBackend(twin, seed=seed, edge_names=tuple(fleet),
                          edge_speed=dict(fleet))
    return PlacementRuntime(eng, backend, prewarm=prewarm,
                            reclamation=reclamation)


def _bursty_tasks(twin, n=400, seed=3, n_tiers=0):
    wl = BurstyWorkload(rate_per_s=2.0, size_sampler=twin.sample_input,
                        burst_multiplier=20.0, mean_quiet_s=20.0,
                        mean_burst_s=5.0, seed=seed)
    tasks = wl.generate(n)
    if n_tiers:
        for i, t in enumerate(tasks):
            t.tier = i % n_tiers
    return tasks


def _assert_records_equal(a, b, cols=RECORD_COLS):
    for col in cols:
        assert np.array_equal(getattr(a.records, col),
                              getattr(b.records, col)), col


PRESSURE_TIERS = (SLOTier(3000.0, sheddable=False), SLOTier(2500.0),
                  SLOTier(2000.0))


def _pressure_runtime(twin, models, **kw):
    """MinCost over the 3-device fleet under a 20x burst backlogs the edge
    queues far past the tier-0 deadline — the reclamation trigger scenario."""
    return _runtime(twin, models, FLEET3,
                    policy=MinCostPolicy(deadline_ms=3000.0), **kw)


# ---------------------------------------------------------- policy validation
def test_prewarm_policy_validation():
    for kw in (dict(count=0), dict(keepalive_ms=0.0),
               dict(keepalive_ms=float("nan")), dict(spinup_ms=-1.0),
               dict(alpha=0.0), dict(alpha=1.5), dict(baseline_alpha=-0.1),
               dict(ratio=1.0), dict(ratio=float("inf")),
               dict(exit_ratio=0.5), dict(exit_ratio=3.0, ratio=3.0),
               dict(min_gaps=0), dict(cooldown_ms=-1.0)):
        with pytest.raises(FaultError):
            PrewarmPolicy(**kw)
    assert PrewarmPolicy(targets=["1792"]).targets == ("1792",)


def test_reclamation_policy_validation():
    two = (SLOTier(100.0, sheddable=False), SLOTier(50.0))
    with pytest.raises(FaultError, match="at least two"):
        ReclamationPolicy(tiers=(SLOTier(100.0),), shares=(1.0,))
    with pytest.raises(FaultError, match="one weight per tier"):
        ReclamationPolicy(tiers=two, shares=(1.0,))
    with pytest.raises(FaultError, match=r"shares\[1\]"):
        ReclamationPolicy(tiers=two, shares=(1.0, 0.0))
    with pytest.raises(FaultError, match="headroom"):
        ReclamationPolicy(tiers=two, shares=(1.0, 1.0), headroom=0.0)
    with pytest.raises(FaultError, match=r"tiers\[1\]\.deadline_ms"):
        ReclamationPolicy(tiers=(SLOTier(50.0), SLOTier(100.0)),
                          shares=(1.0, 1.0))
    pol = ReclamationPolicy(tiers=two, shares=(3, 1))
    assert pol.shares == (3.0, 1.0)
    assert pol.deadline_of(0) == 100.0
    assert pol.deadline_of(99) == 50.0  # clipped to the last class


def test_admission_tier_ordering_validated():
    """Satellite: AdmissionPolicy rejects non-decreasing deadline tables with
    the offending tier indexed (lower classes must degrade first)."""
    with pytest.raises(FaultError, match=r"tiers\[1\]\.deadline_ms"):
        AdmissionPolicy(tiers=(SLOTier(50.0), SLOTier(100.0)))
    with pytest.raises(FaultError, match=r"tiers\[2\]"):
        AdmissionPolicy(tiers=(SLOTier(100.0), SLOTier(50.0), SLOTier(50.0)))
    AdmissionPolicy(tiers=(SLOTier(100.0), SLOTier(50.0)))  # decreasing: ok


def test_retry_policy_nan_rejected():
    for kw in (dict(backoff_ms=float("nan")), dict(backoff_mult=float("nan")),
               dict(timeout_ms=float("nan")), dict(backoff_ms=float("inf"))):
        with pytest.raises(FaultError):
            RetryPolicy(**kw)
    assert RetryPolicy(timeout_ms=float("inf")).timeout_ms == float("inf")


def test_overload_manager_requires_a_policy():
    with pytest.raises(FaultError, match="needs a PrewarmPolicy"):
        OverloadManager()


# ------------------------------------------------------------ CIL encoding
def test_cil_prewarm_window():
    cil = ContainerInfoList(t_idl_ms=27 * 60 * 1000.0)
    with pytest.raises(ValueError, match="keepalive"):
        cil.prewarm("1792", 1000.0, 1000.0)
    cil.prewarm("1792", 1000.0, 61000.0)
    assert not cil.will_warm_start("1792", 999.0)     # still spinning up
    assert cil.will_warm_start("1792", 1000.0)        # warm at ready
    assert cil.will_warm_start("1792", 61000.0)       # warm through expiry
    assert not cil.will_warm_start("1792", 61000.1)   # gone after
    assert not cil.will_warm_start("1536", 30000.0)   # other configs unwarmed


def test_predictor_prewarm_rejects_unknown_targets(fd_setup):
    twin, models = fd_setup
    pred = build_fleet_predictor(models, dict(FLEET3), configs=CONFIGS)
    with pytest.raises(KeyError):
        pred.prewarm("4096", 0.0, 1000.0)     # not a cloud config
    with pytest.raises(KeyError):
        pred.prewarm("edge0", 0.0, 1000.0)    # fleet devices have no CIL
    pred.prewarm("1792", 0.0, 1000.0)
    assert pred.cil.will_warm_start("1792", 500.0)


# -------------------------------------------------------------- forecaster
def _two_burst_arrivals():
    """Deterministic quiet/burst/quiet/burst arrival times (ms)."""
    t, out = 0.0, []
    for gap in ([1000.0] * 30 + [20.0] * 60 + [1000.0] * 40 + [20.0] * 60):
        t += gap
        out.append(t)
    return np.array(out)


def test_forecaster_chunk_invariance():
    arrivals = _two_burst_arrivals()
    whole = BurstForecaster()
    triggers_whole = whole.feed(arrivals)
    assert len(triggers_whole) == 2  # one spawn per quiet->burst transition

    def state(f):
        return (f.last_t, f.fast, f.slow, f.n_gaps, f.in_burst,
                f.last_spawn, f.n_triggers)

    # one arrival at a time
    single = BurstForecaster()
    triggers_single = [t for a in arrivals for t in single.feed([a])]
    assert triggers_single == triggers_whole
    assert state(single) == state(whole)

    # random chunk boundaries
    rng = np.random.default_rng(0)
    for _ in range(5):
        cuts = np.sort(rng.choice(len(arrivals), size=7, replace=False))
        chunked = BurstForecaster()
        got = [t for part in np.split(arrivals, cuts)
               for t in chunked.feed(part)]
        assert got == triggers_whole
        assert state(chunked) == state(whole)


def test_forecaster_cooldown_rate_limits_triggers():
    arrivals = _two_burst_arrivals()
    # burst onsets are ~70 s apart; a 100 s cooldown swallows the second
    lazy = BurstForecaster(cooldown_ms=100_000.0)
    assert len(lazy.feed(arrivals)) == 1
    assert lazy.n_triggers == 1


def test_forecaster_detects_mmpp_bursts(fd_setup):
    """Triggers fire inside ground-truth burst phases of the MMPP source
    (``TaskChunk.burst`` is the phase flag at each arrival)."""
    twin, _ = fd_setup
    wl = BurstyWorkload(rate_per_s=2.0, size_sampler=twin.sample_input,
                        burst_multiplier=20.0, seed=3)
    arrivals, flags = [], {}
    for chunk in wl.chunks(600, chunk_size=128):
        arrivals.append(chunk.arrival_ms)
        for t, b in zip(chunk.arrival_ms.tolist(), chunk.burst.tolist()):
            flags[t] = b
    fc = BurstForecaster()
    triggers = [t for a in arrivals for t in fc.feed(a)]
    assert len(triggers) >= 1
    in_burst = [flags[t] for t in triggers]
    assert sum(in_burst) >= len(in_burst) / 2  # detector lags a few arrivals


def test_chunks_burst_column_matches_generate(fd_setup):
    twin, _ = fd_setup
    wl = BurstyWorkload(rate_per_s=2.0, size_sampler=twin.sample_input,
                        burst_multiplier=20.0, seed=7)
    tasks = wl.generate(300)
    want = np.array([t.meta["burst"] for t in tasks])
    assert want.any() and not want.all()
    got = np.concatenate([c.burst for c in wl.chunks(300, chunk_size=64)])
    assert np.array_equal(got, want)
    # slicing and scalar materialization carry the flag
    chunk = next(iter(wl.chunks(300, chunk_size=64)))
    sub = chunk[10:20]
    assert np.array_equal(sub.burst, chunk.burst[10:20])
    assert sub[3].meta["burst"] == bool(chunk.burst[13])


# ----------------------------------------------------- select_victims (pure)
def test_select_victims_fair_share_semantics():
    pol = ReclamationPolicy(tiers=PRESSURE_TIERS, shares=(2.0, 1.0, 1.0))
    codes = np.array([1, 1, 1, 1, 1, 1])
    tier = np.array([0, 2, 2, 1, 0, 2])
    lat = np.array([100.0, 0.0, 0.0, 0.0, 3500.0, 0.0])
    comp = np.array([50.0, 30.0, 30.0, 40.0, 50.0, 30.0])
    active = np.ones(6, dtype=bool)
    v = select_victims(pol, codes=codes, tier=tier, latency_ms=lat,
                       comp_ms=comp, active=active, n_cloud=1, n_targets=2)
    # tier-2 compute on the device is 90 ms against a fair share of
    # 0.25 * 230 = 57.5 ms -> only ~32.5 ms reclaimable: the earliest tier-2
    # row goes, the rest is protected; tier-1 (40 ms < its 57.5 ms share)
    # is untouchable; row 5 sits behind the pressure point and never
    # eligible; tier-0 rows are never victims.
    assert v.tolist() == [1]
    # no pressure (tier-0 within deadline) -> no victims
    calm = select_victims(pol, codes=codes, tier=tier,
                          latency_ms=np.full(6, 100.0), comp_ms=comp,
                          active=active, n_cloud=1, n_targets=2)
    assert calm.size == 0
    # cloud rows (codes < n_cloud) are never scanned
    cloud = select_victims(pol, codes=np.zeros(6, dtype=np.int64), tier=tier,
                           latency_ms=lat, comp_ms=comp, active=active,
                           n_cloud=1, n_targets=2)
    assert cloud.size == 0


# --------------------------------------------------- accounting invariants
def test_prewarm_billed_exactly_once(fd_setup):
    twin, models = fd_setup
    rt = _runtime(twin, models, FLEET3, prewarm=PrewarmPolicy(count=3))
    pol = rt.engine.policy
    before = pol.surplus
    rt._spawn_prewarm(5_000.0)
    log = rt.overload.prewarm_log
    assert len(log) == 3 * len(CONFIGS)
    costs = [e[4] for e in log]
    assert all(c > 0.0 for c in costs)
    assert pol.surplus == pytest.approx(before - sum(costs), rel=1e-12)
    cil = rt.engine.predictor.cil
    ready = log[0][2]
    for c in CONFIGS:
        assert cil.count(str(c)) == 3
        assert cil.will_warm_start(str(c), ready)
    # keep-alive extensions ride the spawn-time retainer: unbilled
    after_spawn = pol.surplus
    rt.overload.forecaster.in_burst = True
    rt._post_execute([_rec(0, completion_ms=10 ** 7)])
    assert rt.overload.n_extensions == len(log)
    assert pol.surplus == after_spawn
    assert rt.overload.prewarm_log == log  # the spawn ledger is append-only
    assert all(e.expires_ms == 10 ** 7 + rt.overload.prewarm.keepalive_ms
               for e in rt.overload.active_prewarms)


def test_prewarm_cuts_cold_starts(fd_setup):
    twin, models = fd_setup
    tasks = _bursty_tasks(twin)
    off = _runtime(twin, models, FLEET3).serve(tasks)
    rt = _runtime(twin, models, FLEET3, prewarm=PrewarmPolicy(count=4))
    on = rt.serve(tasks)
    ov = rt.overload
    assert ov.forecaster.n_triggers >= 1
    assert len(ov.prewarm_log) == ov.forecaster.n_triggers * 4 * len(CONFIGS)
    assert int(on.records.actual_cold.sum()) < int(off.records.actual_cold.sum())


def test_kept_in_place_rollback_exactness(fd_setup, monkeypatch):
    """Every alternative masked -> every victim is forcibly kept in place:
    the preemption rollback + verbatim re-application must leave surplus,
    predicted horizons, and every physical record column exactly as the
    reclamation-off run — only the SLO bookkeeping (tier / downgraded)
    may move."""
    twin, models = fd_setup
    import repro.core.runtime as rt_mod
    monkeypatch.setattr(rt_mod, "failover_choice", lambda *a, **k: None)
    tasks = _bursty_tasks(twin, n_tiers=3)
    recl = ReclamationPolicy(tiers=PRESSURE_TIERS, shares=(2.0, 1.0, 1.0))
    # MinCost backlogs the fleet via its deadline fallback; MinLatency with a
    # starved budget goes all-edge AND carries the Alg. 1 surplus bank, so
    # the surplus leg of the invariant is exercised too.
    for mk in (lambda: MinCostPolicy(deadline_ms=3000.0),
               lambda: MinLatencyPolicy(c_max=1e-6, alpha=0.02)):
        off = _runtime(twin, models, FLEET3, policy=mk())
        r_off = off.serve(tasks)
        on = _runtime(twin, models, FLEET3, policy=mk(), reclamation=recl)
        r_on = on.serve(tasks)
        log = on.overload.reclaim_log
        assert len(log) > 0
        assert all(e[2] == e[3] and not e[6] for e in log)  # kept: dst == src
        # demoted one class, clipped at the bottom of the table
        nt = len(PRESSURE_TIERS)
        assert all(e[5] == min(e[4] + 1, nt - 1) for e in log)
        assert all(e[7] == (e[5] != e[4]) for e in log)
        assert any(e[7] for e in log)
        assert r_on.n_downgraded == sum(e[7] for e in log)
        # physical outcome bit-identical; only SLO class bookkeeping moved
        phys = tuple(c for c in RECORD_COLS
                     if c not in ("downgraded", "tier"))
        _assert_records_equal(r_off, r_on, cols=phys)
        if hasattr(on.engine.policy, "surplus"):
            assert on.engine.policy.surplus == pytest.approx(
                off.engine.policy.surplus, rel=1e-12)
        for name in FLEET3:
            assert on.edge_queues[name].horizon_ms == pytest.approx(
                off.edge_queues[name].horizon_ms, rel=1e-12)


# ------------------------------------------------------- schedule identity
def test_prewarm_schedule_identity_across_paths(fd_setup):
    twin, models = fd_setup
    tasks = _bursty_tasks(twin)
    pw = PrewarmPolicy(count=2)

    def run(call):
        rt = _runtime(twin, models, FLEET3, prewarm=pw)
        res = call(rt)
        return rt.overload.prewarm_log, res

    log0, r_serve = run(lambda rt: rt.serve(tasks))
    assert len(log0) > 0
    log_a, r_async = run(lambda rt: rt.serve_async(tasks))
    log_s, r_stream = run(
        lambda rt: rt.serve_stream(tasks, chunk_size=len(tasks)))
    # the spawn schedule is a pure fold over arrivals: identical across
    # paths AND chunkings (triggers are arrival times, chunk-invariant)
    assert log_a == log0 and log_s == log0
    for cs in (1, 37):
        log_c, _ = run(lambda rt: rt.serve_stream(tasks, chunk_size=cs))
        assert log_c == log0
    # records agree wherever chunk boundaries agree (PR 8's contract)
    _assert_records_equal(r_serve, r_async)
    _assert_records_equal(r_serve, r_stream)
    # and a re-run reproduces everything bit for bit
    log_r, r_repeat = run(lambda rt: rt.serve(tasks))
    assert log_r == log0
    _assert_records_equal(r_serve, r_repeat)


def test_reclaim_schedule_identity_across_paths(fd_setup):
    twin, models = fd_setup
    tasks = _bursty_tasks(twin, n_tiers=3)
    recl = ReclamationPolicy(tiers=PRESSURE_TIERS, shares=(2.0, 1.0, 1.0))

    def run(call):
        rt = _pressure_runtime(twin, models, reclamation=recl)
        res = call(rt)
        return rt.overload.reclaim_log, res

    log0, r_serve = run(lambda rt: rt.serve(tasks))
    assert len(log0) > 0
    assert any(e[6] for e in log0)  # some victims actually moved
    assert r_serve.n_downgraded == sum(e[7] for e in log0)
    assert np.array_equal(np.nonzero(r_serve.records.downgraded)[0],
                          np.unique([e[1] for e in log0 if e[7]]))
    log_a, r_async = run(lambda rt: rt.serve_async(tasks))
    log_s, r_stream = run(
        lambda rt: rt.serve_stream(tasks, chunk_size=len(tasks)))
    log_r, r_repeat = run(lambda rt: rt.serve(tasks))
    assert log_a == log0 and log_s == log0 and log_r == log0
    _assert_records_equal(r_serve, r_async)
    _assert_records_equal(r_serve, r_stream)
    _assert_records_equal(r_serve, r_repeat)


# ------------------------------------------------------- off / idle parity
IDLE_PREWARM = PrewarmPolicy(min_gaps=10 ** 9)  # forecaster never arms
IDLE_RECLAIM = ReclamationPolicy(                # pressure test never fires
    tiers=(SLOTier(1e15, sheddable=False), SLOTier(1e12)), shares=(1.0, 1.0))


@pytest.mark.parametrize("policy_cls", ["minlat", "mincost"])
def test_armed_but_idle_bit_parity_all_paths(fd_setup, policy_cls):
    """Overload configured but never firing must be bit-identical per record
    to the plain runtime on every serve path — the policies-off guarantee
    plus the armed-but-quiet guarantee in one."""
    twin, models = fd_setup

    def pol():
        if policy_cls == "minlat":
            return MinLatencyPolicy(c_max=2.97e-5, alpha=0.02)
        return MinCostPolicy(deadline_ms=4000.0)

    tasks = _bursty_tasks(twin, n=150, n_tiers=2)
    plain = _runtime(twin, models, FLEET3, policy=pol()).serve(tasks)
    armed = _runtime(twin, models, FLEET3, policy=pol(),
                     prewarm=IDLE_PREWARM, reclamation=IDLE_RECLAIM)
    _assert_records_equal(plain, armed.serve(tasks))
    assert armed.overload.prewarm_log == []
    assert armed.overload.reclaim_log == []
    _assert_records_equal(plain, _runtime(
        twin, models, FLEET3, policy=pol(), prewarm=IDLE_PREWARM,
        reclamation=IDLE_RECLAIM).serve_async(tasks))
    for cs in (1, 37, 4096):
        _assert_records_equal(plain, _runtime(
            twin, models, FLEET3, policy=pol(), prewarm=IDLE_PREWARM,
            reclamation=IDLE_RECLAIM).serve_stream(tasks, chunk_size=cs))


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 50), chunk=st.integers(1, 60))
    def test_idle_parity_property(fd_setup, seed, chunk):
        twin, models = fd_setup
        tasks = twin.workload(60, seed=seed)
        plain = _runtime(twin, models, FLEET3, seed=seed).serve(tasks)
        armed = _runtime(twin, models, FLEET3, seed=seed,
                         prewarm=IDLE_PREWARM, reclamation=IDLE_RECLAIM
                         ).serve_stream(tasks, chunk_size=chunk)
        _assert_records_equal(plain, armed)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_idle_parity_property():
        pass


# ----------------------------------------------------------- record column
def test_downgraded_is_first_class_on_records():
    recs = [_rec(i, completion_ms=float(i) + 1.0, downgraded=(i % 2 == 1))
            for i in range(6)]
    rb = RecordBatch.from_records(recs)
    assert rb.downgraded.tolist() == [False, True] * 3
    assert rb[1].downgraded and not rb[0].downgraded
    assert rb.take(np.array([1, 3, 5])).downgraded.all()
    res = SimulationResult(records=rb)
    assert res.n_downgraded == 3
    assert res.pct_downgraded == pytest.approx(50.0)
