"""Chaos twin + failure-aware serving (ISSUE 8).

Covers:
- ``FaultSpec`` construction validation: overlapping windows rejected with
  the offending entry indexed, bad probabilities / factors / legs named;
- the counter-based fault stream: deterministic, vectorization-invariant,
  per-target independent;
- the EMPTY-SPEC PARITY guarantee: with retry / breaker / admission
  configured but an empty ``FaultSpec``, every serve path is bit-identical
  per record to the plain pre-fault runtime (MinCost and MinLatency, one- and
  three-device fleets, multiple chunk sizes) — plus the hypothesis property;
- failure-path accounting on ``RecordBatch`` columns: retried / failed-over
  tasks bill every attempted leg, shed tasks bill nothing, permanent
  failures carry their attempts and give-up time;
- hedged races with a crashed winner fall to the surviving loser;
- circuit breaker open/half-open behavior through the serve loop;
- cross-run and cross-path determinism of the whole failure schedule;
- fault-schedule capture into a trace and back (``fault_spec_of``);
- ``serve_concurrent`` raising an actionable error naming a dead dispatcher
  instead of hanging forever.
"""

from __future__ import annotations

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # gated, not required: the container may not ship it
    HAVE_HYPOTHESIS = False

from repro.core.decision import (
    DecisionEngine,
    HedgedPolicy,
    MinCostPolicy,
    MinLatencyPolicy,
    PlacementDecision,
)
from repro.core.faults import (
    TRANSIENT,
    AdmissionPolicy,
    Blackout,
    CircuitBreaker,
    ColdSpike,
    FaultError,
    FaultSpec,
    OutageWindow,
    RetryPolicy,
    SLOTier,
    Straggler,
    TargetHealth,
    TransientErrors,
    fault_uniform,
)
from repro.core.fit import build_fleet_predictor, fit_app
from repro.core.predictor import Prediction
from repro.core.runtime import ExecutionOutcome, PlacementRuntime, TwinBackend
from repro.core.workload import TaskInput
from repro.trace.replay import capture, fault_spec_of

CONFIGS = (1280, 1536, 1792)
FLEET3 = {"edge0": 1.0, "edge1": 1.0, "edge2": 0.6}
FLEET1 = {"edge0": 1.0}

RECORD_COLS = ("actual_latency_ms", "actual_cost", "completion_ms",
               "target_codes", "queue_wait_ms", "exec_ms", "predicted_cost",
               "predicted_latency_ms", "attempts", "failed", "shed")


@pytest.fixture(scope="module")
def fd_setup():
    return fit_app("FD", seed=0, n_inputs=120, configs=CONFIGS)


def _runtime(twin, models, fleet, policy=None, faults=None, retry=None,
             admission=None, breaker=None, seed=11):
    pred = build_fleet_predictor(models, dict(fleet), configs=CONFIGS)
    policy = policy or MinLatencyPolicy(c_max=2.97e-5, alpha=0.02)
    eng = DecisionEngine(predictor=pred, policy=policy)
    backend = TwinBackend(twin, seed=seed, edge_names=tuple(fleet),
                          edge_speed=dict(fleet), faults=faults)
    return PlacementRuntime(eng, backend, retry=retry, admission=admission,
                            breaker=breaker)


def _assert_records_equal(a, b, cols=RECORD_COLS):
    for col in cols:
        assert np.array_equal(getattr(a.records, col),
                              getattr(b.records, col)), col


# ------------------------------------------------------------ spec validation
def test_overlapping_outage_windows_rejected():
    with pytest.raises(FaultError, match=r"outages\[1\].*overlaps.*outages\[0\]"):
        FaultSpec(outages=[OutageWindow("edge0", 0.0, 100.0),
                           OutageWindow("edge0", 50.0, 200.0)])


def test_disjoint_windows_and_other_targets_ok():
    spec = FaultSpec(outages=[OutageWindow("edge0", 0.0, 100.0),
                              OutageWindow("edge0", 100.0, 200.0),
                              OutageWindow("edge1", 50.0, 150.0)])
    assert spec.outage_mask("edge0", [50.0, 150.0, 250.0]).tolist() == \
        [True, True, False]
    assert spec.outage_mask("edge1", [50.0]).tolist() == [True]
    assert spec.outage_mask("missing", [50.0]).tolist() == [False]


def test_empty_window_rejected_with_index():
    with pytest.raises(FaultError, match=r"outages\[0\].*empty window"):
        FaultSpec(outages=[OutageWindow("edge0", 100.0, 100.0)])
    with pytest.raises(FaultError, match=r"stragglers\[1\].*start_ms"):
        FaultSpec(stragglers=[Straggler("edge0", 0.0, 1.0, 2.0),
                              Straggler("edge0", -5.0, 1.0, 2.0)])


def test_bad_probability_and_factor_rejected():
    with pytest.raises(FaultError, match=r"transient\[0\].*\[0, 1\]"):
        FaultSpec(transient=[TransientErrors("1792", 1.5)])
    with pytest.raises(FaultError, match=r"cold_spikes\[0\].*positive"):
        FaultSpec(cold_spikes=[ColdSpike("1792", 0.0, 1.0, -2.0)])
    with pytest.raises(FaultError, match=r"blackouts\[0\].*unknown network leg"):
        FaultSpec(blackouts=[Blackout("warp", 0.0, 1.0)])
    with pytest.raises(FaultError, match="detect_ms"):
        FaultSpec(detect_ms=-1.0)


def test_retry_and_breaker_validation():
    with pytest.raises(FaultError, match="max_attempts"):
        RetryPolicy(max_attempts=0)
    with pytest.raises(FaultError, match="backoff_mult"):
        RetryPolicy(backoff_mult=0.5)
    with pytest.raises(FaultError, match="threshold"):
        CircuitBreaker(threshold=0)
    with pytest.raises(FaultError, match="deadline_ms"):
        SLOTier(deadline_ms=0.0)
    assert RetryPolicy(backoff_ms=10.0, backoff_mult=3.0).backoff_for(3) == 90.0


def test_fault_spec_json_round_trip():
    spec = FaultSpec(seed=9, detect_ms=2.5,
                     outages=[OutageWindow("edge0", 1.0, 2.0)],
                     transient=[TransientErrors("1792", 0.25)],
                     cold_spikes=[ColdSpike("1536", 0.0, 9.0, 4.0)],
                     stragglers=[Straggler("edge1", 3.0, 7.0, 2.0)],
                     blackouts=[Blackout("iot", 0.0, 5.0, target="edge0")])
    assert FaultSpec.from_json(spec.to_json()) == spec
    assert not FaultSpec()
    assert spec


# ------------------------------------------------------- counter-based stream
def test_fault_uniform_deterministic_and_vectorized():
    scalar = [fault_uniform(7, "1792", i, 100.0 * i) for i in range(50)]
    block = fault_uniform(7, "1792", np.arange(50), 100.0 * np.arange(50))
    assert np.array_equal(np.array(scalar), block)
    assert np.all((block >= 0.0) & (block < 1.0))
    # different targets / seeds / times decorrelate
    other = fault_uniform(7, "edge0", np.arange(50), 100.0 * np.arange(50))
    assert not np.array_equal(block, other)
    assert fault_uniform(7, "1792", 3, 10.0) != fault_uniform(8, "1792", 3, 10.0)
    assert fault_uniform(7, "1792", 3, 10.0) != fault_uniform(7, "1792", 3, 10.5)


def test_transient_mask_rate_roughly_p():
    spec = FaultSpec(seed=1, transient=[TransientErrors("1792", 0.3)])
    m = spec.transient_mask("1792", np.arange(4000), np.linspace(0, 1e6, 4000))
    assert 0.25 < m.mean() < 0.35
    assert not spec.transient_mask("other", np.arange(10), np.zeros(10)).any()


# --------------------------------------------------------- empty-spec parity
@pytest.mark.parametrize("fleet", [FLEET1, FLEET3])
@pytest.mark.parametrize("policy_cls", ["minlat", "mincost"])
def test_empty_spec_bit_parity_all_paths(fd_setup, fleet, policy_cls):
    """Retry+breaker+admission configured over an EMPTY spec must be
    bit-identical per record to the plain runtime, on every serve path."""
    twin, models = fd_setup
    tasks = twin.workload(150, seed=2)

    def pol():
        if policy_cls == "minlat":
            return MinLatencyPolicy(c_max=2.97e-5, alpha=0.02)
        return MinCostPolicy(deadline_ms=4000.0)

    knobs = dict(faults=FaultSpec(), retry=RetryPolicy(),
                 breaker=CircuitBreaker(),
                 admission=AdmissionPolicy(tiers=(SLOTier(1e12),)))
    plain = _runtime(twin, models, fleet, policy=pol()).serve(tasks)
    fa = _runtime(twin, models, fleet, policy=pol(), **knobs).serve(tasks)
    _assert_records_equal(plain, fa)

    fa_async = _runtime(twin, models, fleet, policy=pol(),
                        **knobs).serve_async(tasks)
    _assert_records_equal(plain, fa_async)

    for cs in (1, 37, 150):
        fa_stream = _runtime(twin, models, fleet, policy=pol(),
                             **knobs).serve_stream(tasks, chunk_size=cs)
        _assert_records_equal(plain, fa_stream)


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 50), chunk=st.integers(1, 60),
           c_max=st.floats(1e-6, 1e-4))
    def test_empty_spec_parity_property(fd_setup, seed, chunk, c_max):
        twin, models = fd_setup
        tasks = twin.workload(60, seed=seed)
        plain = _runtime(twin, models, FLEET3,
                         policy=MinLatencyPolicy(c_max=c_max, alpha=0.02),
                         seed=seed).serve(tasks)
        fa = _runtime(twin, models, FLEET3,
                      policy=MinLatencyPolicy(c_max=c_max, alpha=0.02),
                      seed=seed, faults=FaultSpec(), retry=RetryPolicy(),
                      breaker=CircuitBreaker()).serve_stream(tasks,
                                                             chunk_size=chunk)
        _assert_records_equal(plain, fa)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_empty_spec_parity_property():
        pass


# --------------------------------------------------- failure-path accounting
def test_transient_retry_bills_every_attempt(fd_setup):
    """p=1 transient on every cloud config, no failover: attempts exhaust
    and the task fails, billing every attempted leg."""
    twin, models = fd_setup
    spec = FaultSpec(seed=3, transient=[TransientErrors(f"{c}", 1.0)
                                        for c in CONFIGS])
    tasks = twin.workload(40, seed=4)

    one = _runtime(twin, models, {}, faults=spec,
                   retry=RetryPolicy(max_attempts=1, failover=False),
                   seed=11).serve(tasks)
    three = _runtime(twin, models, {}, faults=spec,
                     retry=RetryPolicy(max_attempts=3, failover=False,
                                       backoff_ms=10.0),
                     seed=11).serve(tasks)
    assert one.n_failed == len(tasks) and three.n_failed == len(tasks)
    assert np.all(one.records.attempts == 1)
    assert np.all(three.records.attempts == 3)
    # every attempted leg billed: 3 attempts cost ≈ 3× the 1-attempt bill
    # (the draws differ per attempt, so compare totals loosely)
    assert three.total_actual_cost > 2.0 * one.total_actual_cost
    assert np.all(three.records.actual_cost > one.records.actual_cost)
    # the give-up time is the last failure detection, after the first
    assert np.all(three.records.completion_ms > one.records.completion_ms)


def test_outage_fails_over_to_surviving_target(fd_setup):
    twin, models = fd_setup
    tasks = twin.workload(120, seed=5)
    horizon = tasks[-1].arrival_ms + 1.0
    spec = FaultSpec(seed=3, outages=[OutageWindow("1792", 0.0, horizon)])
    res = _runtime(twin, models, FLEET3, faults=spec,
                   retry=RetryPolicy(max_attempts=3)).serve(tasks)
    rb = res.records
    # nothing may end on the dead config; failed-over rows took 2 dispatches
    final = {rb.target_names[c] for c in np.unique(rb.target_codes).tolist()}
    assert "1792" not in final
    moved = rb.attempts > 1
    assert moved.any()
    assert res.n_failed == 0
    # an outage dispatch bills nothing but costs detection latency, so
    # failed-over latency strictly exceeds the per-attempt execution time
    assert np.all(rb.actual_latency_ms[moved] > 0.0)


def test_shed_tasks_bill_nothing(fd_setup):
    twin, models = fd_setup
    tasks = twin.workload(100, seed=6)
    for t in tasks:
        t.tier = t.idx % 2   # half top-tier, half sheddable
    adm = AdmissionPolicy(tiers=(SLOTier(1e12, sheddable=False),
                                 SLOTier(1e-9)))  # tier 1: always sheds
    res = _runtime(twin, models, FLEET3, faults=FaultSpec(),
                   admission=adm).serve(tasks)
    rb = res.records
    assert res.n_shed == 50
    assert np.array_equal(rb.shed, rb.tier == 1)
    assert np.all(rb.actual_cost[rb.shed] == 0.0)
    assert np.all(rb.attempts[rb.shed] == 0)
    assert np.all(rb.exec_ms[rb.shed] == 0.0)
    assert np.all(rb.completion_ms[rb.shed] == rb.arrival_ms[rb.shed])
    # top tier untouched and still served
    assert not rb.shed[rb.tier == 0].any()
    assert np.all(rb.attempts[rb.tier == 0] >= 1)
    # shedding shows up as SLO misses for its tier, not the top tier
    assert res.slo_attainment(1e12, tier=1) == 0.0
    assert res.slo_attainment(1e12, tier=0) == 1.0


def test_shed_rollback_restores_decision_state(fd_setup):
    """Serving tier-1 work that all sheds must leave surplus and predicted
    horizons exactly as if only the surviving tasks had been placed."""
    twin, models = fd_setup
    tasks = twin.workload(80, seed=7)
    for t in tasks:
        t.tier = t.idx % 2
    adm = AdmissionPolicy(tiers=(SLOTier(1e12, sheddable=False),
                                 SLOTier(1e-9)))
    rt = _runtime(twin, models, FLEET3, faults=FaultSpec(), admission=adm)
    rt.serve(tasks)
    survivors = [t for t in tasks if t.tier == 0]
    # a fresh runtime serving ONLY the survivors: same decision state after
    rt2 = _runtime(twin, models, FLEET3)
    rt2.serve(survivors)
    assert rt.engine.policy.surplus == pytest.approx(
        rt2.engine.policy.surplus, rel=1e-12)
    for name in FLEET3:
        assert rt.edge_queues[name].horizon_ms == pytest.approx(
            rt2.edge_queues[name].horizon_ms, rel=1e-12)


# --------------------------------------------------------------- hedge races
def _mk_outcome(latency, cost, completion, failed=False, exec_ms=1.0):
    return ExecutionOutcome(latency_ms=latency, cost=cost, cold=False,
                            completion_ms=completion, exec_ms=exec_ms,
                            failed=failed,
                            fail_kind=TRANSIENT if failed else 0)


def _mk_hedge_decision():
    p = Prediction(target="A", latency_ms=100.0, cost=2e-6, cold=False,
                   components={})
    h = Prediction(target="B", latency_ms=120.0, cost=1e-6, cold=False,
                   components={})
    return PlacementDecision(task_idx=0, target="A", prediction=p,
                             feasible=True, allowed_cost=1.0,
                             hedge_target="B", hedge_prediction=h)


def test_hedge_crashed_winner_falls_to_loser(fd_setup):
    twin, models = fd_setup
    rt = _runtime(twin, models, FLEET3)
    task = TaskInput(idx=0, arrival_ms=0.0, size=1e6, bytes=1e5)
    d = _mk_hedge_decision()

    # primary crashed, duplicate survived: the record reports the duplicate
    prim = _mk_outcome(5.0, 3e-6, 5.0, failed=True)
    rec = rt._record(task, d, d.target, d.prediction, prim)
    dup = _mk_outcome(140.0, 1.5e-6, 140.0)
    merged = rt._merge_hedge(rec, task, d, dup)
    assert merged.target == "B" and merged.hedge_target == "A"
    assert not merged.failed and merged.hedged
    assert merged.actual_latency_ms == 140.0
    assert merged.completion_ms == 140.0
    assert merged.actual_cost == pytest.approx(3e-6 + 1.5e-6)  # both billed

    # duplicate crashed, primary survived: primary stands, crash billed
    rec_ok = rt._record(task, d, d.target, d.prediction,
                        _mk_outcome(90.0, 3e-6, 90.0))
    merged2 = rt._merge_hedge(rec_ok, task, d, _mk_outcome(5.0, 1e-6, 5.0,
                                                           failed=True))
    assert merged2.target == "A" and not merged2.failed
    assert merged2.actual_latency_ms == 90.0      # the crash never "wins"
    assert merged2.actual_cost == pytest.approx(3e-6 + 1e-6)

    # both crashed: a failed record
    merged3 = rt._merge_hedge(rec, task, d, _mk_outcome(5.0, 1e-6, 5.0,
                                                        failed=True))
    assert merged3.failed and merged3.hedged


def test_hedged_serve_with_faults_end_to_end(fd_setup):
    """A full hedged serve against a dead config: hedged records never end
    on the dead target, and the run stays deterministic."""
    twin, models = fd_setup
    tasks = twin.workload(120, seed=8)
    horizon = tasks[-1].arrival_ms + 1.0
    spec = FaultSpec(seed=2, outages=[OutageWindow("1792", 0.0, horizon)])

    def run():
        pred = build_fleet_predictor(models, dict(FLEET3), configs=CONFIGS)
        policy = HedgedPolicy(MinLatencyPolicy(c_max=8e-5, alpha=0.0),
                              hedge_threshold_ms=1500.0)
        eng = DecisionEngine(predictor=pred, policy=policy)
        backend = TwinBackend(twin, seed=17, edge_names=tuple(FLEET3),
                              edge_speed=FLEET3, faults=spec)
        return PlacementRuntime(eng, backend).serve(tasks)

    a, b = run(), run()
    hedged = [r for r in a.records if r.hedged]
    assert hedged
    assert all(r.target != "1792" for r in a.records if not r.failed)
    assert [r.target for r in a.records] == [r.target for r in b.records]
    assert a.total_actual_cost == b.total_actual_cost


# ----------------------------------------------------------- circuit breaker
def test_breaker_opens_and_readmits():
    h = TargetHealth(CircuitBreaker(threshold=2, probation_ms=100.0))
    assert not h.is_open("x", 0.0)
    h.record_failure("x", 1.0)
    assert not h.is_open("x", 1.0)      # below threshold
    h.record_failure("x", 2.0)
    assert h.is_open("x", 50.0)         # open, inside probation
    assert h.would_fail_fast("x", 50.0)
    assert not h.is_open("x", 103.0)    # half-open: probe admitted
    h.record_failure("x", 104.0)        # probe failed -> re-open
    assert h.is_open("x", 105.0)
    assert not h.is_open("x", 300.0)    # next probe
    h.record_success("x")
    assert not h.is_open("x", 301.0) and not h.dirty()
    assert h.n_opens == 2


def test_breaker_trips_in_serve_loop(fd_setup):
    twin, models = fd_setup
    tasks = twin.workload(200, seed=9)
    horizon = tasks[-1].arrival_ms + 1.0
    spec = FaultSpec(seed=4, outages=[OutageWindow("1792", 0.0, horizon)])
    rt = _runtime(twin, models, FLEET3, faults=spec,
                  retry=RetryPolicy(max_attempts=3),
                  breaker=CircuitBreaker(threshold=3, probation_ms=1e9))
    res = rt.serve_stream(tasks, chunk_size=20)
    assert rt.health.n_opens >= 1
    assert rt.health.would_fail_fast("1792", tasks[-1].arrival_ms)
    # after the circuit opened, tasks stop burning an attempt on the dead
    # config: some rows fail over on their FIRST dispatch (attempts == 1)
    rb = res.records
    later = rb.arrival_ms > np.median(rb.arrival_ms)
    assert res.n_failed == 0
    assert (rb.attempts[later] == 1).any()


# ------------------------------------------------------ cross-path determinism
def test_faulted_run_identical_across_runs_and_paths(fd_setup):
    twin, models = fd_setup
    tasks = twin.workload(150, seed=10)
    spec = FaultSpec(seed=5,
                     outages=[OutageWindow("1792", 10_000.0, 40_000.0)],
                     transient=[TransientErrors("1536", 0.15)],
                     stragglers=[Straggler("edge2", 0.0, 50_000.0, 3.0)],
                     blackouts=[Blackout("iot", 20_000.0, 30_000.0)])

    def mk():
        return _runtime(twin, models, FLEET3, faults=spec,
                        retry=RetryPolicy(max_attempts=4, backoff_ms=25.0),
                        breaker=CircuitBreaker(threshold=3))

    base = mk().serve(tasks)
    assert base.n_retried > 0
    _assert_records_equal(base, mk().serve(tasks))
    _assert_records_equal(base, mk().serve_async(tasks))
    _assert_records_equal(base, mk().serve_stream(tasks,
                                                  chunk_size=len(tasks)))


# ------------------------------------------------------------- trace capture
def test_fault_schedule_capture_round_trip(fd_setup):
    twin, models = fd_setup
    tasks = twin.workload(60, seed=11)
    spec = FaultSpec(seed=6, transient=[TransientErrors("1536", 0.2)])
    res = _runtime(twin, models, FLEET3, faults=spec,
                   retry=RetryPolicy(max_attempts=2)).serve(tasks)
    trace = capture(res, app="fd", faults=spec)
    assert fault_spec_of(trace) == spec
    assert fault_spec_of(capture(res, app="fd")) is None


# ----------------------------------------------- dead-dispatcher diagnostics
def test_serve_concurrent_names_dead_dispatcher(monkeypatch):
    import threading

    from repro.serving.executors import ExecutorPool, _Dispatch

    pool = object.__new__(ExecutorPool)  # serve_concurrent touches no state
    monkeypatch.setattr(threading.Thread, "start",
                        lambda self: None)  # the dispatcher dies instantly
    plan = [_Dispatch(idx=0, target="cfgA", n_tokens=4, payload_bytes=16.0,
                      arrival_ms=0.0)]
    with pytest.raises(RuntimeError, match="cfgA"):
        ExecutorPool.serve_concurrent(pool, plan)
