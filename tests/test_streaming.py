"""Streaming sharded serve (ISSUE 5): chunked pipeline + multi-app shards.

Covers:
- ``serve_stream`` ≡ one-shot ``serve(batched=True)`` PER RECORD for chunk
  sizes from 1 upward, including boundaries landing inside speculate-and-
  repair segments (small ``COLUMNAR_CHUNK`` + bursty edge/cloud oscillation);
- ``TaskChunk`` columnar workloads: lazy views, slicing, bit-identical
  ``chunks()`` streams for Poisson (block sampler) and Bursty (scalar walk);
- constant-memory mode (``keep_tasks=False``): metrics backed by the arena's
  arrival/index columns, synthesized placeholder task views;
- hedged policies stream through the per-task fallback path, bit-identical;
- out-of-arrival-order streams fall back to the walk exactly like one-shot;
- ``RecordArena``: geometric growth, in-place merge, cross-table code remap
  (hedge ``-1`` passthrough), equivalence with ``RecordBatch.from_records``;
- the ``(id(model), comp_feature)`` GBRT step-table cache: shared across
  chunks and Predictors, invalidated by swapping in a fresh model object;
- always-warm targets never carry a cold component stack in
  ``predict_batch``;
- ``ShardedRuntime``: thread/process/sequential modes produce bit-identical
  per-shard results; factory validation for process mode.
"""

from __future__ import annotations

import functools

import numpy as np
import pytest

import repro.core.decision as decision_mod
import repro.core.predictor as predictor_mod
from repro.core.decision import (
    DecisionEngine,
    HedgedPolicy,
    MinCostPolicy,
    MinLatencyPolicy,
)
from repro.core.fit import build_fleet_predictor, build_predictor, fit_app
from repro.core.multiapp import AppShard, ShardedRuntime, serve_sharded
from repro.core.records import RecordArena, RecordBatch, TaskRecord
from repro.core.runtime import PlacementRuntime, TwinBackend
from repro.core.workload import BurstyWorkload, TaskChunk, TaskInput, task_arrays

CONFIGS = (1280, 1536, 1792)
FLEET = {"edge0": 1.0, "edge1": 1.0, "edge2": 0.6}
NAMES = tuple(FLEET)

RECORD_COLS = ("predicted_latency_ms", "predicted_cost", "actual_latency_ms",
               "actual_cost", "allowed_cost", "completion_ms", "queue_wait_ms",
               "exec_ms", "hedge_exec_ms", "predicted_cold", "actual_cold",
               "feasible", "hedged")


@pytest.fixture(scope="module")
def ir_setup():
    return fit_app("IR", seed=0, n_inputs=120, configs=CONFIGS)


@pytest.fixture(scope="module")
def stt_setup():
    return fit_app("STT", seed=0, n_inputs=120, configs=CONFIGS)


def _runtime(twin, models, c_max=6e-6, alpha=0.05, policy=None, seed=11):
    pred = build_fleet_predictor(models, dict(FLEET), configs=CONFIGS)
    eng = DecisionEngine(
        predictor=pred,
        policy=policy if policy is not None
        else MinLatencyPolicy(c_max=c_max, alpha=alpha))
    backend = TwinBackend(twin, seed=seed, edge_names=NAMES, edge_speed=FLEET)
    return PlacementRuntime(eng, backend)


def _bursty(twin, n, seed=31):
    return BurstyWorkload(rate_per_s=4.0, size_sampler=twin.sample_input,
                          burst_multiplier=8.0, mean_quiet_s=10.0,
                          mean_burst_s=6.0, seed=seed).generate(n)


def assert_records_equal(a: RecordBatch, b: RecordBatch):
    assert len(a) == len(b)
    assert list(a.targets) == list(b.targets)
    for col in RECORD_COLS:
        assert np.array_equal(getattr(a, col), getattr(b, col)), col
    assert np.array_equal(a.arrival_ms, b.arrival_ms)


# -------------------------------------------------- serve_stream bit-parity
def test_serve_stream_equals_one_shot_across_chunk_sizes(ir_setup, monkeypatch):
    """The headline guarantee: chunking changes where passes pause, never
    what they compute — per-record equality for every chunk size, with
    boundaries forced inside repair segments (small speculation windows,
    bursty edge/cloud oscillation → repairs on the one-shot side too)."""
    monkeypatch.setattr(decision_mod, "COLUMNAR_CHUNK", 64)
    twin, models = ir_setup
    tasks = _bursty(twin, 1200)
    ref = _runtime(twin, models).serve(tasks, batched=True)
    for chunk_size in (1, 7, 53, 256, 1200, 5000):
        rt = _runtime(twin, models)
        res = rt.serve_stream(tasks, chunk_size=chunk_size)
        assert_records_equal(res.records, ref.records)
        assert rt.stream_stats["n"] == 1200
    # and the repair machinery was actually exercised somewhere in the stream
    rt = _runtime(twin, models)
    rt.serve_stream(tasks, chunk_size=53)
    assert rt.stream_stats["repairs"] + rt.stream_stats["walked"] > 0


def test_serve_stream_task_chunk_and_chunk_iterator(ir_setup):
    twin, models = ir_setup
    tasks = _bursty(twin, 400, seed=9)
    ref = _runtime(twin, models).serve(tasks, batched=True)

    res = _runtime(twin, models).serve_stream(
        TaskChunk.from_tasks(tasks), chunk_size=97)
    assert_records_equal(res.records, ref.records)

    # a generator of ready TaskChunks (the constant-memory spelling)
    def chunk_gen():
        tc = TaskChunk.from_tasks(tasks)
        for lo in range(0, len(tc), 119):
            yield tc[lo:lo + 119]

    res2 = _runtime(twin, models).serve_stream(chunk_gen())
    assert_records_equal(res2.records, ref.records)

    # an iterator of plain TaskInputs is buffered into chunk_size lists
    res3 = _runtime(twin, models).serve_stream(iter(tasks), chunk_size=61)
    assert_records_equal(res3.records, ref.records)


def test_serve_stream_keep_tasks_false_constant_memory_result(ir_setup):
    twin, models = ir_setup
    tasks = _bursty(twin, 300, seed=12)
    ref = _runtime(twin, models).serve(tasks, batched=True)
    res = _runtime(twin, models).serve_stream(
        TaskChunk.from_tasks(tasks), chunk_size=64, keep_tasks=False)
    assert len(res.records.tasks) == 0
    assert np.array_equal(res.records.arrival_ms,
                          np.array([t.arrival_ms for t in tasks]))
    assert res.records.task_idx is not None
    assert res.records.task_idx.tolist() == [t.idx for t in tasks]
    # metrics all work without task objects
    assert res.avg_actual_latency_ms == ref.avg_actual_latency_ms
    assert res.total_actual_cost == ref.total_actual_cost
    assert res.makespan_ms == ref.makespan_ms
    assert {d: s.n_tasks for d, s in res.device_summaries().items()} == \
        {d: s.n_tasks for d, s in ref.device_summaries().items()}
    # per-record views synthesize placeholder tasks
    rec = res.records[5]
    assert rec.task.meta == {"streamed": True}
    assert rec.task.idx == 5
    assert rec.task.arrival_ms == tasks[5].arrival_ms
    assert np.isnan(rec.task.size)


def test_serve_stream_hedged_policy_fallback_path(ir_setup):
    """Hedged (non-columnar) policies stream through the per-task walk +
    hedge-plan execution — still bit-identical to one-shot, still chunked."""
    twin, models = ir_setup
    tasks = twin.workload(200, seed=5)

    def run(stream):
        policy = HedgedPolicy(MinLatencyPolicy(c_max=8e-5, alpha=0.0),
                              hedge_threshold_ms=1500.0)
        rt = _runtime(twin, models, policy=policy, seed=17)
        if stream:
            return rt.serve_stream(tasks, chunk_size=37)
        return rt.serve(tasks, batched=True)

    a, b = run(True), run(False)
    assert int(np.count_nonzero(a.records.hedged)) > 0
    assert_records_equal(a.records, b.records)
    hc_a = [r.hedge_target for r in a.records]
    hc_b = [r.hedge_target for r in b.records]
    assert hc_a == hc_b


def test_serve_stream_unsorted_stream_falls_back_to_walk(ir_setup):
    """A chunk arriving before the stream's high-water mark flips the whole
    remaining stream to the per-task walk — matching what one-shot
    ``serve(batched=True)`` does when it sees the full unsorted list."""
    twin, models = ir_setup
    tasks = twin.workload(120, seed=6)
    for i, t in enumerate(tasks):
        if i % 7 == 3:
            t.arrival_ms += 5e5  # future spikes: later chunks start "early"
    ref = _runtime(twin, models, c_max=8e-5, alpha=0.02).serve(
        tasks, batched=True)
    rt = _runtime(twin, models, c_max=8e-5, alpha=0.02)
    res = rt.serve_stream(tasks, chunk_size=16)
    assert_records_equal(res.records, ref.records)
    assert rt.stream_stats["walked"] > 0


def test_serve_stream_chunk_size_validation_and_empty(ir_setup):
    twin, models = ir_setup
    rt = _runtime(twin, models)
    with pytest.raises(ValueError, match="chunk_size"):
        rt.serve_stream([], chunk_size=0)
    res = rt.serve_stream([], chunk_size=8)
    assert res.n == 0


# ------------------------------------------------------ columnar workloads
def test_poisson_chunks_bit_identical_to_generate(stt_setup):
    twin, _ = stt_setup
    wl = twin.poisson(seed=5)
    tasks = wl.generate(700)
    chunks = list(wl.chunks(700, chunk_size=64))
    assert all(isinstance(c, TaskChunk) for c in chunks)
    idx = np.concatenate([c.idx for c in chunks])
    arr = np.concatenate([c.arrival_ms for c in chunks])
    size = np.concatenate([c.size for c in chunks])
    nbytes = np.concatenate([c.bytes for c in chunks])
    assert idx.tolist() == [t.idx for t in tasks]
    assert arr.tolist() == [t.arrival_ms for t in tasks]
    assert size.tolist() == [t.size for t in tasks]
    assert nbytes.tolist() == [t.bytes for t in tasks]


def test_bursty_chunks_bit_identical_to_generate(ir_setup):
    twin, _ = ir_setup
    wl = BurstyWorkload(rate_per_s=4.0, size_sampler=twin.sample_input, seed=3)
    tasks = wl.generate(500)
    chunks = list(wl.chunks(500, chunk_size=77))
    arr = np.concatenate([c.arrival_ms for c in chunks])
    size = np.concatenate([c.size for c in chunks])
    assert arr.tolist() == [t.arrival_ms for t in tasks]
    assert size.tolist() == [t.size for t in tasks]
    # the list form still carries the burst flag
    assert {t.meta["burst"] for t in tasks} == {False, True}


def test_sample_input_batch_matches_scalar_loop(ir_setup, stt_setup):
    for twin in (ir_setup[0], stt_setup[0]):
        r1 = np.random.default_rng(4)
        r2 = np.random.default_rng(4)
        got_s, got_b = twin.sample_input_batch(r1, 50)
        exp = [twin.sample_input(r2) for _ in range(50)]
        assert got_s.tolist() == [s for s, _ in exp]
        assert got_b.tolist() == [b for _, b in exp]


def test_task_chunk_views_and_task_arrays(ir_setup):
    twin, _ = ir_setup
    tasks = twin.workload(20, seed=2)
    tc = TaskChunk.from_tasks(tasks)
    assert len(tc) == 20 and bool(tc)
    assert tc[3].arrival_ms == tasks[3].arrival_ms
    assert [t.idx for t in tc[5:9]] == [5, 6, 7, 8]
    idx, arr, size, nbytes = task_arrays(tc)
    assert arr is tc.arrival_ms  # no copy on the columnar path
    idx2, arr2, size2, nbytes2 = task_arrays(tasks)
    assert arr2.tolist() == arr.tolist()
    assert size2.tolist() == size.tolist()


# ------------------------------------------------------------- RecordArena
def _mk_record(i, target="a", hedge=None):
    return TaskRecord(
        task=TaskInput(idx=i, arrival_ms=float(i), size=1.0, bytes=1.0),
        target=target, predicted_latency_ms=i * 1.5, predicted_cost=i * 0.1,
        actual_latency_ms=i * 2.0, actual_cost=i * 0.2,
        predicted_cold=bool(i % 2), actual_cold=bool(i % 3 == 0),
        allowed_cost=float(i), feasible=bool(i % 4), completion_ms=i * 3.0,
        hedged=hedge is not None, queue_wait_ms=0.5 * i, exec_ms=0.25 * i,
        hedge_target=hedge, hedge_exec_ms=1.0 if hedge else 0.0)


def test_arena_growth_and_equivalence_with_from_records():
    records = [_mk_record(i, target=("a", "b", "c")[i % 3],
                          hedge=("b" if i % 5 == 0 else None))
               for i in range(3000)]
    ref = RecordBatch.from_records(records)
    arena = RecordArena(keep_tasks=True, capacity=4)
    # many small appends with shifting per-chunk target tables → growth +
    # remap both exercised
    for lo in range(0, 3000, 17):
        arena.append(records[lo:lo + 17])
    assert len(arena) == 3000
    got = arena.finish()
    assert len(got) == 3000
    for col in RECORD_COLS:
        assert np.array_equal(getattr(got, col), getattr(ref, col)), col
    assert list(got.targets) == list(ref.targets)
    # hedge codes survive the remap, -1 passthrough included
    assert [got.target_names[c] if c >= 0 else None
            for c in got.hedge_codes.tolist()] == \
        [r.hedge_target for r in records]
    assert got.tasks[5] is records[5].task
    # dtypes preserved
    assert got.predicted_cold.dtype == np.bool_
    assert got.target_codes.dtype == np.int64


def test_arena_merges_disjoint_target_tables():
    a = RecordBatch.from_records([_mk_record(0, "x"), _mk_record(1, "y")])
    b = RecordBatch.from_records([_mk_record(2, "z"), _mk_record(3, "x")])
    arena = RecordArena()
    arena.append(a)
    arena.append(b)
    got = arena.finish()
    assert list(got.targets) == ["x", "y", "z", "x"]
    assert got.target_names == ("x", "y", "z")


def test_arena_empty_and_doubling():
    arena = RecordArena()
    assert len(arena.finish()) == 0
    arena.append([])
    assert arena.n == 0
    arena.append([_mk_record(i) for i in range(3)])
    cap0 = arena._cap
    arena.append([_mk_record(i) for i in range(cap0)])
    assert arena._cap >= cap0 * 2  # geometric doubling, not +chunk
    assert arena.nbytes > 0
    got = arena.finish()
    assert len(got) == 3 + cap0
    # rows already appended are never rewritten: the finished view (rows AND
    # its snapshot of the target table) is immune to later appends
    arena.append([_mk_record(99, "zz")])
    assert len(got) == 3 + cap0
    assert "zz" not in got.target_names
    assert "zz" in arena.finish().target_names


def test_arena_keep_tasks_false_columns():
    arena = RecordArena(keep_tasks=False)
    arena.append([_mk_record(i) for i in range(5)])
    got = arena.finish()
    assert got.tasks == []
    assert got.arrivals.tolist() == [0.0, 1.0, 2.0, 3.0, 4.0]
    assert got.task_idx.tolist() == [0, 1, 2, 3, 4]
    assert got[2].task.meta == {"streamed": True}


# ---------------------------------------- GBRT step-table cache (satellite)
def test_const1_cache_shared_across_chunks_and_predictors(stt_setup, monkeypatch):
    """The step table is derived once per (model identity, comp_feature) —
    chunked serving at ANY chunk size, and fresh Predictor objects over the
    same fitted models, reuse it instead of re-deriving per call."""
    twin, models = stt_setup
    model = models.comp_cloud
    calls = {"n": 0}
    orig = type(model).const1_table

    def counting(self, c):
        calls["n"] += 1
        return orig(self, c)

    monkeypatch.setattr(type(model), "const1_table", counting)
    predictor_mod._CONST1_TABLES.clear()
    model.__dict__.pop("_const1_tables", None)

    pred = build_predictor(models, configs=CONFIGS)
    tasks = twin.workload(40, seed=1)
    for lo in range(0, 40, 4):  # 10 small chunks, incl. sub-64-row ones
        pred.predict_batch(tasks[lo:lo + 4])
    assert calls["n"] == len(CONFIGS)  # one derivation per memory config
    # a different Predictor over the SAME model objects also hits the cache
    build_predictor(models, configs=CONFIGS).predict_batch(tasks)
    assert calls["n"] == len(CONFIGS)


def test_const1_cache_invalidated_by_model_swap(stt_setup):
    """Online-refit contract: swapping in a fresh model object must never
    serve the old model's table (identity-keyed with a weakref guard)."""
    import dataclasses

    twin, models = stt_setup
    predictor_mod._CONST1_TABLES.clear()
    x = np.linspace(1e4, 4e5, 200)
    old = models.comp_cloud
    got_old = predictor_mod.gbrt_predict_const(old, x, float(CONFIGS[0]))
    assert np.array_equal(got_old,
                          old.predict(np.stack([x, np.full(200, float(CONFIGS[0]))], 1)))
    # a refit swaps in a FRESH object whose trees differ
    fresh = dataclasses.replace(old, leaves=old.leaves * 2.0)
    fresh.__dict__.pop("_const1_tables", None)
    got_fresh = predictor_mod.gbrt_predict_const(fresh, x, float(CONFIGS[0]))
    assert not np.array_equal(got_fresh, got_old)
    assert np.array_equal(
        got_fresh,
        fresh.predict(np.stack([x, np.full(200, float(CONFIGS[0]))], 1)))


def test_gbrt_predict_const_bit_identical_to_stacked(stt_setup):
    twin, models = stt_setup
    x = np.linspace(1e4, 4e5, 500)
    for c in CONFIGS:
        feats = np.stack([x, np.full(500, float(c))], axis=1)
        assert np.array_equal(
            predictor_mod.gbrt_predict_const(models.comp_cloud, x, float(c)),
            models.comp_cloud.predict(feats))


# ----------------------------------- always-warm cold-skip (satellite)
def test_predict_batch_drops_cold_stack_for_always_warm_targets(ir_setup):
    """A custom always-warm target that naively hands back ``cold = warm``
    must not have the duplicate stack carried (or its latency re-summed)."""
    from repro.core.predictor import Predictor

    class NaiveEdge:
        name = "naive"
        is_edge = True

        def predict_components_batch(self, sizes, nbytes, quantile=None):
            warm = {"comp": np.asarray(sizes, float) * 2.0,
                    "store": np.full(sizes.shape[0], 3.0)}
            return warm, dict(warm)  # the wasteful cold = warm copy

        def predict_components(self, task, cold=False, quantile=None):
            return {"comp": task.size * 2.0, "store": 3.0}

        def cost(self, comp_ms):
            return 0.0

        def occupancy_ms(self, components):
            return components["comp"]

    twin, models = ir_setup
    base = build_predictor(models, configs=CONFIGS)
    pred = Predictor(cloud_targets=base.cloud_targets, edge_target=NaiveEdge())
    batch = pred.predict_batch(twin.workload(10, seed=3))
    tb = batch.edges["naive"]
    assert tb.cold is None and tb.cold_latency is None
    # and the per-task view never reports a cold edge
    view = pred.predict_at(batch, 0, 0.0)
    assert view["naive"].cold is False


# ------------------------------------------------------- sharded serving
def _shard_runtime(app, setups, c_max=0.0):
    twin, models = setups[app]
    pred = build_fleet_predictor(models, dict(FLEET), configs=CONFIGS)
    eng = DecisionEngine(predictor=pred,
                         policy=MinLatencyPolicy(c_max=c_max, alpha=0.0))
    backend = TwinBackend(twin, seed=7, edge_names=NAMES, edge_speed=FLEET)
    return PlacementRuntime(eng, backend)


def _shard_workload(app, setups, n):
    return setups[app][0].poisson(seed=3).chunks(n, chunk_size=256)


@pytest.fixture(scope="module")
def app_setups(ir_setup, stt_setup):
    return {"IR": ir_setup, "STT": stt_setup}


def _make_shards(setups, n=600):
    return [AppShard(name=app,
                     runtime=functools.partial(_shard_runtime, app, setups),
                     workload=functools.partial(_shard_workload, app, setups, n),
                     chunk_size=256)
            for app in setups]


def test_sharded_thread_equals_sequential_per_record(app_setups):
    shards = _make_shards(app_setups)
    seq = ShardedRuntime(shards).serve(parallel=False)
    thr = serve_sharded(shards)  # thread mode default
    assert seq.mode == "sequential" and thr.mode == "thread"
    assert set(seq.results) == set(thr.results) == set(app_setups)
    for app in app_setups:
        assert_records_equal(thr.results[app].records, seq.results[app].records)
    assert thr.n == seq.n == 600 * len(app_setups)
    table = thr.table()
    for app in app_setups:
        assert app in table
    assert "TOTAL" in table


def test_sharded_process_mode_equals_sequential(app_setups):
    shards = _make_shards(app_setups, n=200)
    seq = ShardedRuntime(shards).serve(parallel=False)
    proc = ShardedRuntime(shards).serve(parallel=True, use_processes=True)
    assert proc.mode == "process"
    for app in app_setups:
        assert_records_equal(proc.results[app].records,
                             seq.results[app].records)


def test_sharded_process_mode_requires_factories(app_setups):
    rt = _shard_runtime("IR", app_setups)
    shard = AppShard(name="IR", runtime=rt, workload=[])
    with pytest.raises(ValueError, match="factories"):
        ShardedRuntime([shard]).serve(parallel=True, use_processes=True)


def test_sharded_validation(app_setups):
    shards = _make_shards(app_setups, n=10)
    with pytest.raises(ValueError, match="duplicate"):
        ShardedRuntime(shards + [shards[0]])
    with pytest.raises(ValueError, match="at least one"):
        ShardedRuntime([])

    bad = AppShard(name="bad", runtime=lambda: 42, workload=[])
    with pytest.raises(TypeError, match="PlacementRuntime"):
        bad.resolve_runtime()


def test_sharded_stream_stats_and_walls(app_setups):
    shards = _make_shards(app_setups, n=300)
    res = ShardedRuntime(shards).serve(parallel=False)
    for app in app_setups:
        assert res.stream_stats[app]["n"] == 300
        assert res.wall_s[app] > 0.0
    assert res.elapsed_s >= max(res.wall_s.values()) * 0.99
