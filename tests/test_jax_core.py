"""Device-resident placement core (ISSUE 7): jax serve ≡ numpy serve.

Covers:
- the parity contract: ``serve_stream(array_backend="jax_interpret")`` is
  BIT-IDENTICAL per record to the numpy oracle — every float column, every
  target — across MinCost/MinLatency × 1-/3-device fleets × chunk sizes
  {1, 53, 4096}, with decision-chunk boundaries forced inside repair
  segments (small ``COLUMNAR_CHUNK``, bursty edge/cloud oscillation);
- compiled mode (``array_backend="jax"``): decision-identical targets and
  float columns within tolerance (XLA contracts mul+add chains into FMAs,
  so compiled floats may differ in the last ulp);
- load balancers (RoundRobin/Random) consume their nomination state exactly
  once per chunk — parity holds and the balancer cursor matches numpy's;
- fallbacks: hedged policies, out-of-arrival-order streams and
  ``record_decisions`` take the numpy path with identical results
  (``engine.jax_stats`` stays unset);
- ``array_backend`` validation on both ``DecisionEngine`` and
  ``serve_stream``, and ``serve_stream`` restoring the engine's backend;
- the per-engine core cache (``core_for``) and the jit compile caches: a
  second same-shape chunk must NOT retrace (``compile_stats`` stable);
- ``GBRT.predict_jax`` operand hosting: cached per model identity,
  invalidated by swapping in a fresh model;
- a hypothesis property (skipped when hypothesis is missing): random
  Poisson-ish streams keep interpret parity record-for-record.
"""

from __future__ import annotations

import numpy as np
import pytest

pytest.importorskip("jax")

import repro.core.decision as decision_mod
from repro.core import gbrt as gbrt_mod
from repro.core import jax_core
from repro.core.decision import (
    DecisionEngine,
    HedgedPolicy,
    MinCostPolicy,
    MinLatencyPolicy,
    RandomBalancer,
    RoundRobinBalancer,
)
from repro.core.fit import build_fleet_predictor, fit_app
from repro.core.gbrt import GBRT, GBRTConfig
from repro.core.records import RecordBatch
from repro.core.runtime import PlacementRuntime, TwinBackend
from repro.core.workload import BurstyWorkload, TaskInput

CONFIGS = (1280, 1536, 1792)
FLEET3 = {"edge0": 1.0, "edge1": 1.0, "edge2": 0.6}
FLEET1 = {"edge0": 1.0}

RECORD_COLS = ("predicted_latency_ms", "predicted_cost", "actual_latency_ms",
               "actual_cost", "allowed_cost", "completion_ms", "queue_wait_ms",
               "exec_ms", "hedge_exec_ms", "predicted_cold", "actual_cold",
               "feasible", "hedged")

FLOAT_COLS = ("predicted_latency_ms", "predicted_cost", "actual_latency_ms",
              "actual_cost", "allowed_cost", "completion_ms", "queue_wait_ms",
              "exec_ms")


@pytest.fixture(scope="module")
def ir_setup():
    return fit_app("IR", seed=0, n_inputs=120, configs=CONFIGS)


def _runtime(twin, models, fleet=FLEET3, policy=None, balancer=None, seed=11):
    pred = build_fleet_predictor(models, dict(fleet), configs=CONFIGS)
    eng = DecisionEngine(
        predictor=pred,
        policy=policy if policy is not None
        else MinLatencyPolicy(c_max=6e-6, alpha=0.05),
        balancer=balancer)
    backend = TwinBackend(twin, seed=seed, edge_names=tuple(fleet),
                          edge_speed=fleet)
    return PlacementRuntime(eng, backend)


def _bursty(twin, n, seed=31):
    return BurstyWorkload(rate_per_s=4.0, size_sampler=twin.sample_input,
                          burst_multiplier=8.0, mean_quiet_s=10.0,
                          mean_burst_s=6.0, seed=seed).generate(n)


def assert_records_equal(a: RecordBatch, b: RecordBatch):
    assert len(a) == len(b)
    assert list(a.targets) == list(b.targets)
    for col in RECORD_COLS:
        assert np.array_equal(getattr(a, col), getattr(b, col)), col
    assert np.array_equal(a.arrival_ms, b.arrival_ms)


def _policies():
    return [("min_latency", lambda: MinLatencyPolicy(c_max=6e-6, alpha=0.05)),
            ("min_cost", lambda: MinCostPolicy(deadline_ms=250.0))]


# ------------------------------------------------- interpret-mode bit parity
@pytest.mark.parametrize("policy_name,policy_fn", _policies())
@pytest.mark.parametrize("fleet", [FLEET1, FLEET3],
                         ids=["1dev", "3dev"])
@pytest.mark.parametrize("chunk_size,n", [(1, 60), (53, 300), (4096, 300)],
                         ids=["chunk1", "chunk53", "chunk4096"])
def test_interpret_bit_parity(ir_setup, monkeypatch, policy_name, policy_fn,
                              fleet, chunk_size, n):
    """The headline guarantee: the device core replays the EXACT sequential
    semantics — per-record float equality against the numpy oracle, with the
    oracle's own speculation windows forced small so repairs happen."""
    monkeypatch.setattr(decision_mod, "COLUMNAR_CHUNK", 64)
    twin, models = ir_setup
    tasks = _bursty(twin, n)
    ref = _runtime(twin, models, fleet, policy_fn()).serve_stream(
        tasks, chunk_size=chunk_size)
    rt = _runtime(twin, models, fleet, policy_fn())
    res = rt.serve_stream(tasks, chunk_size=chunk_size,
                          array_backend="jax_interpret")
    assert_records_equal(res.records, ref.records)
    stats = rt.engine.jax_stats
    assert stats is not None and stats["interpret"] and stats["n"] >= 1


@pytest.mark.parametrize("balancer_fn", [
    lambda: RoundRobinBalancer(), lambda: RandomBalancer(seed=5)],
    ids=["roundrobin", "random"])
def test_interpret_parity_with_balancers(ir_setup, balancer_fn):
    """Balancer nomination state is consumed exactly once per chunk, in
    arrival order — parity per record AND the cursor/rng advance matches."""
    twin, models = ir_setup
    tasks = _bursty(twin, 240)
    ref_rt = _runtime(twin, models, balancer=balancer_fn())
    ref = ref_rt.serve_stream(tasks, chunk_size=96)
    rt = _runtime(twin, models, balancer=balancer_fn())
    res = rt.serve_stream(tasks, chunk_size=96, array_backend="jax_interpret")
    assert_records_equal(res.records, ref.records)
    a, b = ref_rt.engine.balancer, rt.engine.balancer
    if isinstance(a, RoundRobinBalancer):
        assert a._i == b._i
    else:
        assert a.rng.integers(1 << 30) == b.rng.integers(1 << 30)


# --------------------------------------------- compiled decision equality
@pytest.mark.parametrize("policy_name,policy_fn", _policies())
def test_compiled_decision_equality(ir_setup, policy_fn, policy_name):
    """Compiled XLA fuses mul+add into FMAs, so floats may move in the last
    ulp — but every decision (target, cold, feasible) must be identical and
    every float within tolerance."""
    twin, models = ir_setup
    tasks = _bursty(twin, 400)
    ref = _runtime(twin, models, policy=policy_fn()).serve_stream(
        tasks, chunk_size=128)
    rt = _runtime(twin, models, policy=policy_fn())
    res = rt.serve_stream(tasks, chunk_size=128, array_backend="jax")
    ra, rb = ref.records, res.records
    assert list(ra.targets) == list(rb.targets)
    for col in ("predicted_cold", "actual_cold", "feasible", "hedged"):
        assert np.array_equal(getattr(ra, col), getattr(rb, col)), col
    for col in FLOAT_COLS:
        np.testing.assert_allclose(
            getattr(ra, col).astype(float), getattr(rb, col).astype(float),
            rtol=1e-9, atol=1e-12, err_msg=col)
    assert rt.engine.jax_stats is not None
    assert not rt.engine.jax_stats["interpret"]


# ------------------------------------------------------- fallback regression
def test_hedged_policy_falls_back_to_numpy(ir_setup):
    twin, models = ir_setup
    tasks = _bursty(twin, 200)
    mk = lambda: HedgedPolicy(MinLatencyPolicy(c_max=6e-6, alpha=0.05),
                              hedge_threshold_ms=50.0)
    ref = _runtime(twin, models, policy=mk()).serve_stream(tasks,
                                                           chunk_size=64)
    rt = _runtime(twin, models, policy=mk())
    res = rt.serve_stream(tasks, chunk_size=64, array_backend="jax")
    assert_records_equal(res.records, ref.records)
    assert getattr(rt.engine, "jax_stats", None) is None  # numpy path ran


def test_out_of_order_stream_falls_back(ir_setup):
    twin, models = ir_setup
    tasks = _bursty(twin, 120)
    tasks[10], tasks[50] = tasks[50], tasks[10]
    ref = _runtime(twin, models).serve_stream(tasks, chunk_size=1000)
    rt = _runtime(twin, models)
    res = rt.serve_stream(tasks, chunk_size=1000, array_backend="jax")
    assert_records_equal(res.records, ref.records)
    assert getattr(rt.engine, "jax_stats", None) is None


def test_record_decisions_falls_back(ir_setup):
    twin, models = ir_setup
    pred = build_fleet_predictor(models, dict(FLEET3), configs=CONFIGS)
    eng = DecisionEngine(predictor=pred,
                         policy=MinLatencyPolicy(c_max=6e-6, alpha=0.05),
                         record_decisions=True, array_backend="jax")
    backend = TwinBackend(twin, seed=11, edge_names=tuple(FLEET3),
                          edge_speed=FLEET3)
    rt = PlacementRuntime(eng, backend)
    tasks = _bursty(twin, 80)
    res = rt.serve_stream(tasks, chunk_size=80)
    assert len(eng.decisions) == 80
    assert getattr(eng, "jax_stats", None) is None
    ref = _runtime(twin, models).serve_stream(tasks, chunk_size=80)
    assert_records_equal(res.records, ref.records)


# ----------------------------------------------------- backend plumbing
def test_array_backend_validation(ir_setup):
    twin, models = ir_setup
    pred = build_fleet_predictor(models, dict(FLEET3), configs=CONFIGS)
    with pytest.raises(ValueError, match="array_backend"):
        DecisionEngine(predictor=pred,
                       policy=MinLatencyPolicy(c_max=6e-6, alpha=0.05),
                       array_backend="cupy")
    rt = _runtime(twin, models)
    with pytest.raises(ValueError, match="array_backend"):
        rt.serve_stream(_bursty(twin, 4), array_backend="cupy")


def test_serve_stream_restores_engine_backend(ir_setup):
    twin, models = ir_setup
    rt = _runtime(twin, models)
    assert rt.engine.array_backend == "numpy"
    rt.serve_stream(_bursty(twin, 40), chunk_size=40,
                    array_backend="jax_interpret")
    assert rt.engine.array_backend == "numpy"


def test_core_cache_and_no_retrace(ir_setup):
    """One core per engine config, and the second same-shape chunk reuses
    every jit cache entry — the no-retrace guarantee the bench smoke checks."""
    twin, models = ir_setup
    rt = _runtime(twin, models)
    tasks = _bursty(twin, 384)
    # two warmup chunks: the first grows the container-pool cap (a real shape
    # change), the second compiles at the steady-state shapes
    rt.serve_stream(tasks[:256], chunk_size=128, array_backend="jax")
    core = jax_core.core_for(rt.engine)
    assert core is not None and core.valid_for(rt.engine)
    assert jax_core.core_for(rt.engine) is core  # cached, not rebuilt
    before = core.compile_stats()
    rt.serve_stream(tasks[256:], chunk_size=128, array_backend="jax")
    assert jax_core.core_for(rt.engine) is core
    assert core.compile_stats() == before  # steady shapes ⇒ no retrace


# ------------------------------------------------- GBRT jax operand cache
def test_predict_jax_operand_cache(rng):
    x = rng.uniform(0.0, 100.0, size=(200, 2))
    y = (x[:, 0] * 1.5 + np.sin(x[:, 1])) * 10.0
    m = GBRT.fit(x, y, GBRTConfig(n_trees=12, max_depth=3))
    np.testing.assert_allclose(np.asarray(m.predict_jax(x)), m.predict(x),
                               rtol=1e-6)
    ops1 = gbrt_mod._jax_operands(m)
    assert gbrt_mod._jax_operands(m) is ops1  # hosted once per identity
    # refit-by-swap: a fresh model must get fresh operands
    m2 = GBRT.fit(x, y * 2.0, GBRTConfig(n_trees=12, max_depth=3))
    ops2 = gbrt_mod._jax_operands(m2)
    assert ops2 is not ops1
    np.testing.assert_allclose(np.asarray(m2.predict_jax(x)), m2.predict(x),
                               rtol=1e-6)


# --------------------------------------------------- hypothesis property
def test_random_streams_keep_interpret_parity(ir_setup):
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    twin, models = ir_setup

    @given(
        gaps=st.lists(st.floats(min_value=0.0, max_value=2000.0,
                                allow_nan=False), min_size=3, max_size=24),
        size_seed=st.integers(min_value=0, max_value=2**31 - 1),
        chunk=st.sampled_from([1, 5, 64]),
    )
    @settings(max_examples=15, deadline=None)
    def prop(gaps, size_seed, chunk):
        r = np.random.default_rng(size_seed)
        t = 0.0
        tasks = []
        for i, g in enumerate(gaps):
            t += g
            size, nbytes = twin.sample_input(r)
            tasks.append(TaskInput(idx=i, arrival_ms=t, size=size,
                                   bytes=nbytes))
        ref = _runtime(twin, models).serve_stream(tasks, chunk_size=chunk)
        res = _runtime(twin, models).serve_stream(
            tasks, chunk_size=chunk, array_backend="jax_interpret")
        assert_records_equal(res.records, ref.records)

    prop()


# ------------------------------------------------- stream residency (ISSUE 9)
@pytest.mark.parametrize("chunk_size,n", [(1, 60), (53, 300), (4096, 300)],
                         ids=["chunk1", "chunk53", "chunk4096"])
def test_resident_stream_parity_and_sync_counts(ir_setup, monkeypatch,
                                                chunk_size, n):
    """Cross-chunk device residency: per-record bit parity with the one-shot
    numpy oracle AND exactly ONE host materialization for the whole clean
    stream (the stream-end sync) — chunk boundaries stop being sync points."""
    monkeypatch.setattr(decision_mod, "COLUMNAR_CHUNK", 64)
    twin, models = ir_setup
    tasks = _bursty(twin, n)
    ref = _runtime(twin, models).serve_stream(tasks, chunk_size=chunk_size)
    rt = _runtime(twin, models)
    res = rt.serve_stream(tasks, chunk_size=chunk_size,
                          array_backend="jax_interpret")
    assert_records_equal(res.records, ref.records)
    r = rt.stream_stats["residency"]
    assert r["enabled"]
    assert r["resident_chunks"] == rt.stream_stats["chunks"]
    assert r["chunk_commits"] == 0
    assert r["state_syncs"] == 1 and r["fallback_syncs"] == 0
    if rt.stream_stats["chunks"] > 1:
        assert r["prefetched"] >= 1  # the transfer thread staged chunks


def test_resident_midstream_fallback_and_reentry(ir_setup):
    """A hedged chunk mid-stream exits residency through ONE fallback sync
    (host walk sees canonical state), and the following chunks re-enter
    residency with state intact — parity vs the numpy oracle under the same
    policy-swap schedule."""
    twin, models = ir_setup
    tasks = _bursty(twin, 300)

    def swapping_chunks(rt):
        # chunks 0-1 resident, chunk 2 hedged (host walk), chunks 3-4 resident
        orig = rt.engine.policy
        hedged = HedgedPolicy(MinLatencyPolicy(c_max=6e-6, alpha=0.05),
                              hedge_threshold_ms=50.0)
        for i in range(5):
            if i == 2:
                rt.engine.policy = hedged
            elif i == 3:
                rt.engine.policy = orig
            yield tasks[i * 60:(i + 1) * 60]

    ref_rt = _runtime(twin, models)
    ref = ref_rt.serve_stream(swapping_chunks(ref_rt), chunk_size=60)
    rt = _runtime(twin, models)
    # prefetch off: the transfer thread pulls chunk k+1 (firing the swap
    # side effect) while chunk k is still placing, which would reorder the
    # schedule this test pins down
    res = rt.serve_stream(swapping_chunks(rt), chunk_size=60,
                          array_backend="jax_interpret", prefetch=False)
    assert_records_equal(res.records, ref.records)
    core = jax_core.core_for(rt.engine)
    assert core is not None
    assert core.resident_chunks == 4
    assert core.fallback_syncs == 1    # the hedged chunk's exit
    assert core.state_syncs == 2       # fallback exit + stream end
    assert core.chunk_commits == 0


def test_resident_pool_growth_donation_safety(ir_setup):
    """Compiled mode donates the state seed into the jitted step; a resident
    chunk whose cold starts overflow the pool must restore the seed from the
    device-side backup, compact/grow, and re-run — no use-after-donate, and
    decisions stay identical to numpy."""
    twin, models = ir_setup
    tasks = _bursty(twin, 400)
    ref = _runtime(twin, models).serve_stream(tasks, chunk_size=64)
    rt = _runtime(twin, models)
    res = rt.serve_stream(tasks, chunk_size=64, array_backend="jax")
    ra, rb = ref.records, res.records
    assert list(ra.targets) == list(rb.targets)
    for col in ("predicted_cold", "actual_cold", "feasible"):
        assert np.array_equal(getattr(ra, col), getattr(rb, col)), col
    core = jax_core.core_for(rt.engine)
    assert core is not None
    assert core.resident_regrows >= 1  # the donated-seed retry path ran
    r = rt.stream_stats["residency"]
    assert r["chunk_commits"] == 0 and r["state_syncs"] == 1


def test_resident_state_syncs_for_external_place_many(ir_setup):
    """An out-of-stream ``place_many`` between two resident streams sees the
    canonical host state: stream 1's end sync landed it, and the standalone
    call commits per chunk like before residency existed."""
    twin, models = ir_setup
    tasks = _bursty(twin, 200)
    ref_rt = _runtime(twin, models)
    ref1 = ref_rt.serve_stream(tasks[:80], chunk_size=40)
    ref_mid = ref_rt.serve(tasks[80:120])
    ref2 = ref_rt.serve_stream(tasks[120:], chunk_size=40)
    rt = _runtime(twin, models)
    res1 = rt.serve_stream(tasks[:80], chunk_size=40,
                           array_backend="jax_interpret")
    rt.engine.array_backend = "jax_interpret"
    res_mid = rt.serve(tasks[80:120])
    rt.engine.array_backend = "numpy"
    res2 = rt.serve_stream(tasks[120:], chunk_size=40,
                           array_backend="jax_interpret")
    assert_records_equal(res1.records, ref1.records)
    assert_records_equal(res_mid.records, ref_mid.records)
    assert_records_equal(res2.records, ref2.records)
    core = jax_core.core_for(rt.engine)
    assert core.chunk_commits >= 1  # the standalone call committed host-side
