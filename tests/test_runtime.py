"""The unified placement runtime (ISSUE 1): backend/policy/batch contracts.

Covers:
- ``Predictor.predict_batch``/``predict_at`` parity with per-task ``predict``;
- ``DecisionEngine.place_many`` parity with a ``place()`` loop;
- ``PlacementRuntime`` batched vs step-wise equivalence, and the ``Simulation``
  shim being a faithful thin wrapper;
- the formal ``Policy`` protocol (``constraints()``, engine validation);
- ``HedgedPolicy`` budget accounting: the hedge draws down surplus, surplus
  never underflows, and hedged duplicates show up in the cost metrics.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core.decision import (
    DecisionEngine,
    HedgedPolicy,
    MinCostPolicy,
    MinLatencyPolicy,
    PolicyConstraints,
    PredictedEdgeQueue,
)
from repro.core.fit import build_predictor, fit_app
from repro.core.predictor import Prediction
from repro.core.runtime import PlacementRuntime, TwinBackend
from repro.core.simulator import Simulation

CONFIGS = (1280, 1536, 1792)
N_TASKS = 150


@pytest.fixture(scope="module")
def fd_setup():
    return fit_app("FD", seed=0, n_inputs=120, configs=CONFIGS)


# ------------------------------------------------------------ batched predict
@pytest.mark.parametrize("quantile", [None, 0.95])
def test_predict_batch_matches_per_task(fd_setup, quantile):
    """predict_at(batch, i) must equal predict(task) — including after CIL
    state makes some targets warm and some cold."""
    twin, models = fd_setup
    tasks = twin.workload(40, seed=1)

    pred_a = build_predictor(models, configs=CONFIGS, quantile=quantile)
    pred_b = build_predictor(models, configs=CONFIGS, quantile=quantile)
    batch = pred_b.predict_batch(tasks)

    for i, task in enumerate(tasks):
        now = task.arrival_ms
        per = pred_a.predict(task, now, edge_queue_wait_ms=12.5)
        bat = pred_b.predict_at(batch, i, now, edge_queue_wait_ms=12.5)
        assert per.keys() == bat.keys()
        for name in per:
            assert per[name].cold == bat[name].cold
            np.testing.assert_allclose(bat[name].latency_ms, per[name].latency_ms,
                                       rtol=1e-12)
            np.testing.assert_allclose(bat[name].cost, per[name].cost, rtol=1e-12)
            assert per[name].components.keys() == bat[name].components.keys()
        # dispatch to a config on both predictors: later tasks see it warm
        if i % 3 == 0:
            chosen = str(CONFIGS[0])
            pred_a.update_cil(chosen, now, per[chosen])
            pred_b.update_cil(chosen, now, bat[chosen])


def test_place_many_matches_place_loop(fd_setup):
    twin, models = fd_setup
    tasks = twin.workload(N_TASKS, seed=2)

    eng_loop = DecisionEngine(predictor=build_predictor(models, configs=CONFIGS),
                              policy=MinLatencyPolicy(c_max=2.97e-5, alpha=0.02),
                              record_decisions=True)
    queue = PredictedEdgeQueue()
    for t in tasks:
        d = eng_loop.place(t, t.arrival_ms,
                           edge_queue_wait_ms=queue.wait_ms(t.arrival_ms))
        if d.target == eng_loop.edge_name:
            queue.push(t.arrival_ms, d.prediction.comp_ms)

    eng_batch = DecisionEngine(predictor=build_predictor(models, configs=CONFIGS),
                               policy=MinLatencyPolicy(c_max=2.97e-5, alpha=0.02))
    decisions = eng_batch.place_many(tasks)

    assert [d.target for d in decisions] == [d.target for d in eng_loop.decisions]
    for a, b in zip(decisions, eng_loop.decisions):
        np.testing.assert_allclose(a.prediction.latency_ms, b.prediction.latency_ms,
                                   rtol=1e-12)
        np.testing.assert_allclose(a.allowed_cost, b.allowed_cost, rtol=1e-12)
        assert a.prediction.cold == b.prediction.cold


def test_empty_workload_serves_cleanly(fd_setup):
    twin, models = fd_setup
    eng = DecisionEngine(predictor=build_predictor(models, configs=CONFIGS),
                         policy=MinLatencyPolicy(c_max=2.97e-5, alpha=0.02))
    res = PlacementRuntime(eng, TwinBackend(twin, seed=0)).serve([])
    assert res.n == 0 and res.c_max == 2.97e-5


# -------------------------------------------------------------- unified loop
def test_runtime_batched_equals_stepwise(fd_setup):
    twin, models = fd_setup
    tasks = twin.workload(N_TASKS, seed=3)

    def run(batched):
        eng = DecisionEngine(predictor=build_predictor(models, configs=CONFIGS),
                             policy=MinLatencyPolicy(c_max=2.97e-5, alpha=0.02))
        rt = PlacementRuntime(eng, TwinBackend(twin, seed=11))
        return rt.serve(tasks, batched=batched)

    a, b = run(True), run(False)
    assert [r.target for r in a.records] == [r.target for r in b.records]
    assert a.total_actual_cost == b.total_actual_cost
    assert a.avg_actual_latency_ms == b.avg_actual_latency_ms


def test_simulation_shim_is_thin_wrapper(fd_setup):
    """Simulation(...).run must equal driving PlacementRuntime directly."""
    twin, models = fd_setup
    tasks = twin.workload(80, seed=4)

    eng1 = DecisionEngine(predictor=build_predictor(models, configs=CONFIGS),
                          policy=MinCostPolicy(deadline_ms=4500.0))
    res1 = Simulation(twin, eng1, seed=13).run(tasks)

    eng2 = DecisionEngine(predictor=build_predictor(models, configs=CONFIGS),
                          policy=MinCostPolicy(deadline_ms=4500.0))
    res2 = PlacementRuntime(eng2, TwinBackend(twin, seed=13)).serve(tasks)

    assert [r.target for r in res1.records] == [r.target for r in res2.records]
    assert res1.total_actual_cost == res2.total_actual_cost
    assert res1.deadline_ms == 4500.0 and res2.deadline_ms == 4500.0


# ------------------------------------------------------------ Policy protocol
def test_policy_constraints_accessors():
    assert MinCostPolicy(4500.0).constraints() == PolicyConstraints(deadline_ms=4500.0)
    assert MinLatencyPolicy(2e-5, 0.1).constraints() == PolicyConstraints(c_max=2e-5)
    hedged = HedgedPolicy(MinLatencyPolicy(2e-5, 0.1), hedge_threshold_ms=100.0)
    assert hedged.constraints() == PolicyConstraints(c_max=2e-5)  # composition-safe


def test_engine_rejects_non_policy(fd_setup):
    _, models = fd_setup

    class NotAPolicy:
        pass

    with pytest.raises(TypeError, match="Policy"):
        DecisionEngine(predictor=build_predictor(models, configs=CONFIGS),
                       policy=NotAPolicy())


# ------------------------------------------------- hedged budget accounting
def _preds(entries):
    return {
        name: Prediction(target=name, latency_ms=lat, cost=cost, cold=False,
                         components={"comp": lat})
        for name, lat, cost in entries
    }


def test_hedged_surplus_never_underflows_and_trails_baseline():
    """The hedge's cost draws down the surplus bank; the bank must stay ≥ 0 at
    every step (any α — a hedge only ever spends the *remaining* allowance),
    and with α=0 (identical choices) it can never exceed the non-hedged bank."""
    rng = np.random.default_rng(0)
    base0 = MinLatencyPolicy(c_max=2.0, alpha=0.0)
    hedged0 = HedgedPolicy(MinLatencyPolicy(c_max=2.0, alpha=0.0),
                           hedge_threshold_ms=50.0)
    hedged_bank = HedgedPolicy(MinLatencyPolicy(c_max=2.0, alpha=0.5),
                               hedge_threshold_ms=50.0)
    n_hedges = 0
    for _ in range(200):
        entries = [(f"c{i}", float(rng.uniform(10, 200)), float(rng.uniform(0, 4)))
                   for i in range(4)]
        preds = _preds(entries + [("edge", 500.0, 0.0)])
        for policy in (base0, hedged0, hedged_bank):
            name, _, _ = policy.choose(preds)
            policy.observe(preds[name])
        n_hedges += hedged0.last_hedge is not None
        assert hedged0.surplus >= -1e-12
        assert hedged_bank.surplus >= -1e-12
        assert hedged0.surplus <= base0.surplus + 1e-12
    assert n_hedges > 0, "scenario must actually trigger hedges"


class _StubTarget:
    def __init__(self, name, latency, cost, is_edge=False):
        self.name = name
        self.is_edge = is_edge
        self._lat, self._cost = latency, cost

    def predict_components(self, task, cold=False, quantile=None):
        return {"comp": self._lat}

    def cost(self, comp_ms):
        return self._cost

    def occupancy_ms(self, components):
        return components["comp"]


class _StubBackend:
    """Deterministic backend: actual == predicted latency, fixed costs."""

    def __init__(self, latencies, costs):
        self.latencies, self.costs = latencies, costs
        self.executed: list[str] = []

    def probe_cold(self, target, now):
        return False

    def execute(self, task, target, now):
        from repro.core.runtime import ExecutionOutcome

        self.executed.append(target)
        lat = self.latencies[target]
        return ExecutionOutcome(latency_ms=lat, cost=self.costs[target],
                                cold=False, completion_ms=now + lat)


def test_hedged_duplicate_merged_into_record():
    """Both legs billed, first completion wins, violations see combined cost."""
    from repro.core.predictor import Predictor
    from repro.core.workload import TaskInput

    # primary "fast" (lat 100, cost 2.0) is over the 50ms hedge threshold;
    # backup "slow" (lat 120, cost 1.5) fits the remaining budget (4 - 2).
    targets = [_StubTarget("fast", 100.0, 2.0), _StubTarget("slow", 120.0, 1.5)]
    edge = _StubTarget("edge", 5000.0, 0.0, is_edge=True)
    policy = HedgedPolicy(MinLatencyPolicy(c_max=4.0, alpha=0.0),
                          hedge_threshold_ms=50.0)
    eng = DecisionEngine(predictor=Predictor(cloud_targets=targets, edge_target=edge),
                         policy=policy)
    backend = _StubBackend(latencies={"fast": 100.0, "slow": 80.0, "edge": 5000.0},
                           costs={"fast": 2.0, "slow": 1.5, "edge": 0.0})
    rt = PlacementRuntime(eng, backend)
    task = TaskInput(idx=0, arrival_ms=0.0, size=1.0, bytes=1.0)
    res = rt.serve([task])

    assert backend.executed == ["fast", "slow"]  # duplicate dispatch happened
    rec = res.records[0]
    assert rec.hedged and rec.target == "fast"
    assert rec.actual_cost == pytest.approx(3.5)        # both legs billed
    assert rec.predicted_cost == pytest.approx(3.5)
    assert rec.actual_latency_ms == pytest.approx(80.0)  # first completion wins
    assert rec.predicted_latency_ms == pytest.approx(100.0)
    # the hedge's cost drew down the surplus bank: 4 - 2 (primary) - 1.5 (dup)
    assert policy.surplus == pytest.approx(0.5)
    # budget violations are judged on the COMBINED cost of both legs
    assert rec.allowed_cost == pytest.approx(4.0)
    assert res.pct_cost_violated == 0.0


def test_hedged_run_bills_duplicates_end_to_end(fd_setup):
    """A hedged FD run must actually hedge, and every hedged record carries
    the combined (two-leg) cost against its decision-time budget."""
    twin, models = fd_setup
    tasks = twin.workload(N_TASKS, seed=5)
    c_max = 8e-5  # leave headroom so backups fit the remaining budget

    policy = HedgedPolicy(MinLatencyPolicy(c_max=c_max, alpha=0.0),
                          hedge_threshold_ms=1500.0)
    eng = DecisionEngine(predictor=build_predictor(models, configs=CONFIGS),
                         policy=policy, record_decisions=True)
    res = PlacementRuntime(eng, TwinBackend(twin, seed=17)).serve(tasks)

    n_hedged = sum(r.hedged for r in res.records)
    assert n_hedged > 0, "scenario must actually trigger hedges"
    assert policy.surplus >= -1e-12  # the bank never underflows (α=0 ⇒ ≥ 0)
    hedged_decisions = [d for d in eng.decisions if d.hedge_target is not None]
    assert len(hedged_decisions) == n_hedged
    for d in hedged_decisions:
        # the hedge hook only nominates backups that fit the remaining budget
        assert d.hedge_target != d.target
        assert d.prediction.cost + d.hedge_prediction.cost <= d.allowed_cost + 1e-12
