"""Table II: end-to-end latency MAPE of the fitted models (80:20 split).

Paper Table II (%):   IR      FD      STT
    Cloud (warm)      25.38   13.24   14.56
    Edge               2.15    3.78   15.70

The qualitative claims validated: errors land in the paper's band (< ~16% for
most pipelines); IR-cloud is the hardest (highest variance, paper Fig. 3);
edge pipelines are more predictable than cloud for the camera apps.
"""

from __future__ import annotations

import time

from benchmarks.common import banner, fitted

PAPER = {"IR": (25.38, 2.15), "FD": (13.24, 3.78), "STT": (14.56, 15.70)}


def run(emit):
    banner("Table II — end-to-end latency MAPE (%), cloud(warm) / edge")
    print(f"{'app':<5} {'cloud paper':>12} {'cloud ours':>11} "
          f"{'edge paper':>11} {'edge ours':>10}")
    for app in ("IR", "FD", "STT"):
        t0 = time.perf_counter()
        _, models = fitted(app)
        fit_s = time.perf_counter() - t0
        pc, pe = PAPER[app]
        print(f"{app:<5} {pc:>11.2f}% {models.cloud_e2e_mape:>10.2f}% "
              f"{pe:>10.2f}% {models.edge_e2e_mape:>9.2f}%")
        emit(f"table2/{app}", fit_s * 1e6,
             f"cloud_mape={models.cloud_e2e_mape:.2f}%"
             f";edge_mape={models.edge_e2e_mape:.2f}%")


if __name__ == "__main__":
    from benchmarks.common import CsvSink

    sink = CsvSink()
    run(sink)
    print(sink.dump())
