"""Table I: mean component latencies (ms) — twin calibration check.

Paper Table I (ms):
          warm   cold   store(cloud)  iotup  store(edge)
    IR     162    741    549           n/a    579
    FD     163   1500    584           25     583
    STT    145   1404    533           27     579
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.apps import APPS, AWSTwin
from benchmarks.common import banner

PAPER = {
    "IR": dict(warm=162, cold=741, store_cloud=549, iotup=0, store_edge=579),
    "FD": dict(warm=163, cold=1500, store_cloud=584, iotup=25, store_edge=583),
    "STT": dict(warm=145, cold=1404, store_cloud=533, iotup=27, store_edge=579),
}


def run(emit):
    banner("Table I — mean component latencies (ms): twin vs. paper")
    print(f"{'app':<5} {'component':<12} {'paper':>8} {'twin':>8} {'err%':>7}")
    n = 2000
    for app, spec in APPS.items():
        twin = AWSTwin(spec=spec, seed=1)
        rng = np.random.default_rng(2)
        t0 = time.perf_counter()
        ours = {
            "warm": np.mean([twin.start_ms(False, rng) for _ in range(n)]),
            "cold": np.mean([twin.start_ms(True, rng) for _ in range(n)]),
            "store_cloud": np.mean([twin.store_cloud_ms(rng) for _ in range(n)]),
            "iotup": np.mean([twin.iotup_ms(rng) for _ in range(n)]),
            "store_edge": np.mean([twin.store_edge_ms(rng) for _ in range(n)]),
        }
        us = (time.perf_counter() - t0) / (5 * n) * 1e6
        worst = 0.0
        for comp, ref in PAPER[app].items():
            got = ours[comp]
            err = abs(got - ref) / ref * 100 if ref else 0.0
            worst = max(worst, err)
            print(f"{app:<5} {comp:<12} {ref:>8.0f} {got:>8.1f} {err:>6.2f}%")
        emit(f"table1/{app}", us, f"worst_component_err={worst:.2f}%")


if __name__ == "__main__":
    from benchmarks.common import CsvSink

    sink = CsvSink()
    run(sink)
    print(sink.dump())
