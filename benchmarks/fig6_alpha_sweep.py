"""Fig. 6: average end-to-end latency and remaining budget vs. α.

Paper claims validated qualitatively (best Table-IV config set per app):
- increasing α decreases average end-to-end latency (more surplus usable);
- α = 0 collapses to (mostly) edge execution with queueing blow-up;
- predicted average latency tracks actual.
"""

from __future__ import annotations

from repro.core.decision import MinLatencyPolicy
from benchmarks.common import banner, simulate

BEST = {
    "IR": ((1408, 1664, 2944), 5.33442e-06),
    "FD": ((1536, 1664, 2048), 2.96997e-05),
    "STT": ((1152, 1280, 1664), 3.0747e-05),
}
ALPHAS = [0.0, 0.01, 0.02, 0.03, 0.05]


def run(emit):
    banner("Fig. 6 — avg latency and % budget remaining vs α")
    for app, (configs, c_max) in BEST.items():
        print(f"\n[{app}] configs={configs} C_max=${c_max:.6g}")
        print(f"{'α':>5} {'avg actual s':>13} {'avg pred s':>11} "
              f"{'err%':>6} {'budget rem%':>12} {'edge#':>6}")
        lats = []
        for a in ALPHAS:
            res, us = simulate(
                app, lambda c=c_max, aa=a: MinLatencyPolicy(c, aa), configs,
                seed=17)
            rem = 100.0 - res.pct_budget_used
            lats.append(res.avg_actual_latency_ms)
            print(f"{a:>5.2f} {res.avg_actual_latency_ms/1e3:>13.4f} "
                  f"{res.avg_predicted_latency_ms/1e3:>11.4f} "
                  f"{res.latency_error_pct:>5.1f}% {rem:>11.1f}% "
                  f"{res.n_edge:>6d}")
            emit(f"fig6/{app}/alpha={a}", us,
                 f"avg_ms={res.avg_actual_latency_ms:.1f};rem={rem:.1f}%")
        assert lats[-1] <= lats[0] * 1.05, \
            f"{app}: latency should not grow with α"
        print(f"  α=0 → α={ALPHAS[-1]}: "
              f"{lats[0]/1e3:.3f}s → {lats[-1]/1e3:.3f}s")


if __name__ == "__main__":
    from benchmarks.common import CsvSink

    sink = CsvSink()
    run(sink)
    print(sink.dump())
