"""Table V: the live prototype — placement over REAL JAX executions.

The TPU-fleet analog of the paper's AWS prototype run (Sec. VI-B): slice
configs are real jit-compiled models (cold start = real XLA compile + init);
the min-latency policy places a Poisson LLM request stream; every latency is
a wall-clock measurement. Paper headline numbers for FD: 5.65% latency
prediction error, 86% budget used, 1.33% budget violations, 0.83% warm/cold
mismatches, and ~3 orders of magnitude vs. edge-only.

Also reproduces the edge-only comparison: the same workload forced through
the single-slot edge queue.
"""

from __future__ import annotations

import time

from repro.configs import smoke_config
from repro.core.decision import MinLatencyPolicy
from repro.serving.executors import SliceSpec
from repro.serving.placement import (
    calibrate_catalog,
    llm_workload,
    make_live_runtime,
)
from benchmarks.common import banner

N_REQUESTS = 200
RATE = 60.0            # requests/s (virtual arrival clock): ~5× edge capacity
MEAN_TOKENS = 4096.0   # edge ≈ 80 ms/task; slices 2–8× faster
C_MAX = 2.0e-4         # $/task — the 8-chip slice needs banked surplus
ALPHA = 0.02
T_IDL_MS = 4_000.0     # short idle horizon → real warm/cold dynamics


def run(emit):
    banner("Table V — live prototype: placement over real JAX executions")
    cfg = smoke_config("llama3.2-1b")
    specs = [SliceSpec("slice2", 2, tokens_per_step=4),
             SliceSpec("slice4", 4, tokens_per_step=4),
             SliceSpec("slice8", 8, tokens_per_step=4)]
    from repro.core.pricing import SlicePricing

    t0 = time.perf_counter()
    cat = calibrate_catalog(cfg, specs, n_tasks=16, n_cold=2, seed=0,
                            pricing=SlicePricing(quantum_s=0.1),
                            mean_tokens=MEAN_TOKENS)
    calib_s = time.perf_counter() - t0
    print(f"calibration: {calib_s:.1f}s  "
          f"cold={cat.start_cold.mean:.0f}±{cat.start_cold.std:.0f} ms  "
          f"warm={cat.start_warm.mean:.2f} ms")

    tasks = llm_workload(N_REQUESTS, rate_per_s=RATE, seed=1,
                         mean_tokens=MEAN_TOKENS)

    t0 = time.perf_counter()
    runtime = make_live_runtime(cat, MinLatencyPolicy(C_MAX, ALPHA),
                                t_idl_ms=T_IDL_MS)
    res = runtime.serve(tasks)
    serve_s = time.perf_counter() - t0

    # edge-only comparison (paper Sec. VI-B final paragraph)
    runtime0 = make_live_runtime(cat, MinLatencyPolicy(0.0, 0.0),
                                 t_idl_ms=T_IDL_MS)
    res0 = runtime0.serve(tasks)
    speedup = res0.avg_actual_latency_ms / max(res.avg_actual_latency_ms, 1e-9)

    hist = {}
    for r in res.records:
        hist[r.target] = hist.get(r.target, 0) + 1

    print(f"\n{'metric':<28} {'paper (FD/AWS)':>15} {'ours (LLM/slices)':>18}")
    print(f"{'latency pred error':<28} {'5.65 %':>15} "
          f"{res.latency_error_pct:>17.2f}%")
    print(f"{'budget violations':<28} {'1.33 %':>15} "
          f"{res.pct_cost_violated:>17.2f}%")
    print(f"{'% budget used':<28} {'86 %':>15} {res.pct_budget_used:>17.1f}%")
    print(f"{'warm/cold mismatches':<28} {'0.83 %':>15} "
          f"{res.n_warm_cold_mismatches / res.n * 100:>17.2f}%")
    print(f"{'avg e2e latency':<28} {'1.71 s':>15} "
          f"{res.avg_actual_latency_ms:>15.1f}ms")
    print(f"{'edge-only avg latency':<28} {'2404 s':>15} "
          f"{res0.avg_actual_latency_ms:>15.1f}ms")
    print(f"{'placement vs edge-only':<28} {'~1400x':>15} {speedup:>16.1f}x")
    print(f"placement histogram: {dict(sorted(hist.items()))}")

    emit("table5/live", serve_s / N_REQUESTS * 1e6,
         f"lat_err={res.latency_error_pct:.2f}%"
         f";mismatch={res.n_warm_cold_mismatches}/{res.n}"
         f";budget={res.pct_budget_used:.1f}%"
         f";edge_only_speedup={speedup:.1f}x")


if __name__ == "__main__":
    from benchmarks.common import CsvSink

    sink = CsvSink()
    run(sink)
    print(sink.dump())
