"""Beyond-paper extensions (DESIGN.md §8), each vs. the paper-faithful baseline.

1. Quantile (P95) latency predictors — the paper's own stated future work
   ("explicitly incorporate the high variance"): fewer deadline violations on
   the high-variance STT app at ~equal cost.
2. Hedged dispatch — duplicate high-tail placements; measures p99 reduction
   against the extra budget drawn.
"""

from __future__ import annotations

from repro.core.decision import HedgedPolicy, MinCostPolicy, MinLatencyPolicy
from benchmarks.common import banner, simulate


def run(emit):
    banner("Beyond-paper 1 — quantile predictors vs mean (STT, δ=5.5s)")
    configs = (768, 1152, 1280, 1664)
    print(f"{'predictor':<12} {'% viol':>8} {'avg viol ms':>12} {'total $':>12}")
    base = None
    for q in (None, 0.85, 0.95):
        res, us = simulate("STT", lambda: MinCostPolicy(5500.0), configs,
                           seed=21, quantile=q)
        name = "mean" if q is None else f"P{int(q*100)}"
        print(f"{name:<12} {res.pct_deadline_violated:>7.2f}% "
              f"{res.avg_violation_ms:>12.2f} {res.total_actual_cost:>12.8f}")
        emit(f"beyond/quantile/{name}", us,
             f"viol={res.pct_deadline_violated:.2f}%"
             f";cost={res.total_actual_cost:.8f}")
        if q is None:
            base = res
    print(f"  (baseline mean-predictor violations: "
          f"{base.pct_deadline_violated:.2f}%)")

    banner("Beyond-paper 2 — hedged dispatch tail latency (FD, min-latency)")
    configs = (1536, 1664, 2048)
    c_max, alpha = 2.96997e-05, 0.02
    print(f"{'policy':<12} {'avg s':>8} {'p95 s':>8} {'p99 s':>8} "
          f"{'total $':>12} {'% budget':>9}")
    rows = {}
    for name, factory in (
        ("baseline", lambda: MinLatencyPolicy(c_max, alpha)),
        ("hedged", lambda: HedgedPolicy(MinLatencyPolicy(c_max, alpha),
                                        hedge_threshold_ms=2500.0)),
    ):
        res, us = simulate("FD", factory, configs, seed=23)
        rows[name] = res
        print(f"{name:<12} {res.avg_actual_latency_ms/1e3:>8.3f} "
              f"{res.p95_actual_latency_ms/1e3:>8.3f} "
              f"{res.p99_actual_latency_ms/1e3:>8.3f} "
              f"{res.total_actual_cost:>12.8f} {res.pct_budget_used:>8.1f}%")
        emit(f"beyond/hedge/{name}", us,
             f"p99_s={res.p99_actual_latency_ms/1e3:.3f}"
             f";cost={res.total_actual_cost:.8f}")
    dp99 = (rows["baseline"].p99_actual_latency_ms
            - rows["hedged"].p99_actual_latency_ms)
    print(f"  hedging cuts p99 by {dp99/1e3:.3f}s "
          f"for +${rows['hedged'].total_actual_cost - rows['baseline'].total_actual_cost:.8f}")


if __name__ == "__main__":
    from benchmarks.common import CsvSink

    sink = CsvSink()
    run(sink)
    print(sink.dump())
