"""Decision-loop throughput: batched ``place_many`` vs the per-task loop.

The batched Predictor API evaluates every component model (ridge / normal /
GBRT) once over all tasks × targets instead of per task — the GBRT compute
model alone turns N×M Python tree walks into M vectorized ones. This
microbenchmark places a 10k-task FD workload both ways, verifies the
decisions are identical, and reports the throughput ratio (the ISSUE-1
acceptance bar is ≥5x; in practice it is >50x).

    PYTHONPATH=src:. python benchmarks/bench_runtime.py [--n 10000]
"""

from __future__ import annotations

import argparse
import time

from repro.core.decision import DecisionEngine, MinLatencyPolicy, PredictedEdgeQueue
from repro.core.fit import build_predictor, fit_app
from benchmarks import common
from benchmarks.common import banner

CONFIGS = (1280, 1536, 1792, 2048)
C_MAX, ALPHA = 2.97e-5, 0.02


def _fresh_engine(models):
    pred = build_predictor(models, configs=CONFIGS)
    return DecisionEngine(predictor=pred, policy=MinLatencyPolicy(C_MAX, ALPHA))


def run(emit, n: int | None = None):
    if n is None:
        n = 2_000 if common.REDUCED else 10_000
    banner(f"bench_runtime — batched place_many vs per-task place ({n} tasks)")
    twin, models = fit_app("FD", seed=0, n_inputs=200, configs=CONFIGS)
    tasks = twin.workload(n, seed=3)

    # --- per-task decision loop (the pre-redesign serve path) --------------
    eng_loop = _fresh_engine(models)
    queue = PredictedEdgeQueue()
    t0 = time.perf_counter()
    for t in tasks:
        d = eng_loop.place(t, t.arrival_ms,
                           edge_queue_wait_ms=queue.wait_ms(t.arrival_ms))
        if d.target == eng_loop.edge_name:
            queue.push(t.arrival_ms, d.prediction.comp_ms)
    loop_s = time.perf_counter() - t0

    # --- batched decision loop --------------------------------------------
    eng_batch = _fresh_engine(models)
    t0 = time.perf_counter()
    decisions = eng_batch.place_many(tasks)
    batch_s = time.perf_counter() - t0

    mismatches = sum(a.target != b.target
                     for a, b in zip(eng_loop.decisions, decisions))
    speedup = loop_s / max(batch_s, 1e-12)
    print(f"{'path':<22} {'wall s':>10} {'tasks/s':>12}")
    print(f"{'per-task place()':<22} {loop_s:>10.3f} {n / loop_s:>12.0f}")
    print(f"{'place_many()':<22} {batch_s:>10.3f} {n / batch_s:>12.0f}")
    print(f"speedup: {speedup:.1f}x   decision mismatches: {mismatches}/{n}")
    assert mismatches == 0, "batched decisions diverged from per-task loop"
    assert speedup >= 5.0, f"expected >=5x, got {speedup:.1f}x"

    emit("runtime/place_per_task", loop_s / n * 1e6, f"n={n}")
    emit("runtime/place_many", batch_s / n * 1e6,
         f"n={n};speedup={speedup:.1f}x")


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--n", type=int, default=None)
    args = p.parse_args()
    from benchmarks.common import CsvSink

    sink = CsvSink()
    run(sink, n=args.n)
    print(sink.dump())


if __name__ == "__main__":
    main()
