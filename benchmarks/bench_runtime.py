"""Runtime throughput benchmarks: the columnar decision core, vectorized twin
execution, end-to-end serve, and the edge-fleet scenario.

Sections (run all via ``python benchmarks/run.py --only runtime``, or this
file directly; ``--smoke`` on run.py exercises the parity-critical sections in
seconds for CI; ``--json`` writes the machine-readable ``BENCH_runtime.json``):

1. **decision** — the columnar ``place_many`` (vectorized policy kernels +
   speculate-and-repair, ISSUE-3) vs the per-task decision walk over the same
   batched predictions (the pre-columnar ``place_many``) vs the per-task
   ``place()`` loop, on the 100k-task saturated-fleet workload. Decisions
   must be identical across all three; columnar ≥ 10x the walk (acceptance
   bar) and far above the step loop. A mixed edge/cloud budget is also
   reported (repairs are denser there, so the ratio is lower).
2. **serve** — end-to-end ``PlacementRuntime.serve`` on the same scenario:
   the array-native path (``DecisionBatch`` → ``execute_many`` →
   ``RecordBatch``) vs the legacy object path (walk decisions + per-task
   outcome/record objects); bit-identical results, ≥ 5x (acceptance bar).
3. **twin-exec** — vectorized ``TwinBackend.execute_many`` vs the sequential
   ``execute`` loop on a 100k-task saturated-fleet workload (3 edge devices,
   bursty arrivals, edge-first budget). Outcomes must be bit-identical —
   ``execute_many`` consumes the same RNG streams — and throughput ≥ 10x.
   A mixed edge/cloud split is also reported (the cloud container-pool walk
   is inherently sequential, so its ratio is lower).
4. **fleet** — skewed (bursty) arrivals on a heterogeneous 3-device fleet:
   least-predicted-wait balancing must beat round-robin, and the fleet must
   beat the single-edge configuration on mean end-to-end latency. Per-device
   utilization/queue-wait summaries show the balance.
5. **async-overlap** — the live event-driven driver (ISSUE 4):
   ``serve_async`` over the REAL executor pool on a saturated 3-device edge
   fleet with emulated WAN result-upload legs (``NetworkProfile`` — genuine
   wall-clock waits standing in for the paper's network legs) vs the
   sequential live driver on the identical workload. Wall-clock overlap
   speedup must clear the floor (≥ 2x full, relaxed in smoke): per-device
   worker threads hide each other's network waits and interleave compute up
   to the local core budget. Real compiles + real executions; identical task
   counts and placement on both sides.
6. **million** — the 1M-task columnar scenario (full runs only): previously
   impractical (minutes of per-task object churn); now end-to-end serve in
   seconds, entirely on arrays.
7. **streaming-scale** (ISSUE 5) — ``serve_stream`` at 10M tasks: arrival
   chunks through the columnar pipeline with a ``RecordArena`` result,
   O(chunk) working set instead of the one-shot path's O(n × targets)
   prediction matrices. Asserts a peak-RSS ceiling (full) / tracemalloc
   ceiling (smoke) AND a throughput floor ≥ the one-shot serve rate measured
   in the same run. Plus **sharded**: ``serve_sharded`` running the IR+FD+STT
   application mix as parallel shards (threads and the process fallback) vs
   sequential per-app serves — per-record parity asserted across all modes;
   the ≥2x wall-clock floor is asserted on machines with ≥ 4 cores (CPU-bound
   shards cannot physically exceed ~1x on the 2-core CI class, where the
   parity check is the bench's value; the measured speedup is reported
   either way).
8. **trace-planner** (ISSUE 6) — replaying a recorded 50k-task trace
   (``repro.trace.TraceWorkload``) must match the equivalent in-memory
   stream per record AND land within 1.2x of its wall time (replay slices
   arrays instead of sampling); plus an 8-candidate what-if capacity search
   (``repro.planner``, successive halving over fleet sizes × policies) whose
   winner must be the cheapest SLO-meeting config, verified on the full
   trace.
9. **jax-core** (ISSUE 7) — the device-resident predict→place pipeline
   (``repro.core.jax_core``) vs the numpy columnar path. Full: a 1M-task
   steady stream served with ``array_backend="jax"``; on an accelerator the
   device core must clear ≥ 2x the numpy rate (on CPU the measured ratio is
   report-only — XLA's sequential-scan overhead dominates there, the
   decision-equality assertion is the CPU value). Smoke: a small-N parity
   gate — ``"jax_interpret"`` bit-identical per record to the oracle,
   compiled ``"jax"`` decision-identical — plus the compile-cache check:
   after a warmup serve, a second same-shape stream must NOT retrace
   (``JaxPlacementCore.compile_stats()`` stable). Both variants also time
   ``SCAN_MODE="seq"`` vs ``"assoc"`` on compiled streams and audit the
   ``"auto"`` table (``jax_core._AUTO_SCAN``) against the measured winner —
   asserted at full size on accelerators, report-only row on CPU.
10. **chaos** (ISSUE 8) — the deterministic fault-injection layer. Faults-off
    overhead: retry + breaker + admission armed over an EMPTY ``FaultSpec``
    must be bit-identical per record to the plain serve AND within 3% of its
    rate at full size (relaxed in smoke; the parity gate never is).
    Degradation: 1 of 3 edge devices down for the middle 30% of the run plus
    a flaky cloud config — retry/failover/breaker/shedding must carry the
    top (non-sheddable) SLO tier to ≥99% attainment.
11. **residency** (ISSUE 9) — persistent device-resident streaming. A steady
    compiled stream keeps CIL pools / surplus / horizons device-side across
    chunks: every chunk must place resident (zero per-chunk host commits,
    zero fallback syncs, at most the one stream-end materialization), stay
    decision-identical to the per-chunk ``device_residency=False`` path and,
    on an accelerator, beat its rate. A hedged chunk mid-stream must cost
    exactly ONE extra (fallback) sync with residency re-entered afterwards.

    PYTHONPATH=src:. python benchmarks/bench_runtime.py [--n 10000]
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.decision import (
    DecisionBatch,
    DecisionEngine,
    LeastPredictedWaitBalancer,
    MinLatencyPolicy,
    PredictedEdgeQueue,
    RoundRobinBalancer,
)
from repro.core.fit import build_fleet_predictor, build_predictor, fit_app
from repro.core.records import RecordBatch
from repro.core.runtime import PlacementRuntime, TwinBackend
from repro.core.workload import BurstyWorkload
from benchmarks import common
from benchmarks.common import banner

CONFIGS = (1280, 1536, 1792, 2048)
C_MAX, ALPHA = 2.97e-5, 0.02

# the fleet scenario: two full-speed devices + one slower straggler
FLEET_SPEEDS = {"edge0": 1.0, "edge1": 1.0, "edge2": 0.6}
FLEET_NAMES = tuple(FLEET_SPEEDS)
FLEET_C_MAX = 2e-6  # edge-first budget: bursts must be absorbed by the fleet


def _bursty(twin, n: int, rate_per_s: float = 4.0, seed: int = 7):
    return BurstyWorkload(rate_per_s=rate_per_s, size_sampler=twin.sample_input,
                          burst_multiplier=6.0, mean_quiet_s=15.0,
                          mean_burst_s=6.0, seed=seed).generate(n)


def _fleet_engine(models, c_max=0.0, alpha=0.0, columnar=True, **kwargs):
    pred = build_fleet_predictor(models, dict(FLEET_SPEEDS), configs=CONFIGS)
    return DecisionEngine(predictor=pred,
                          policy=MinLatencyPolicy(c_max=c_max, alpha=alpha),
                          columnar=columnar, **kwargs)


def _warm_model_caches(models, tasks):
    """Build the per-(model, memory) GBRT step tables once so best-of-reps
    timing measures the steady state, not one-time cache construction."""
    build_fleet_predictor(models, dict(FLEET_SPEEDS),
                          configs=CONFIGS).predict_batch(tasks[:64])


# ------------------------------------------------- 1. the columnar decisions
def _decision_case(emit, models, tasks, label, c_max, alpha, min_speedup,
                   step_n: int, reps: int = 3):
    n = len(tasks)
    col_s = walk_s = float("inf")
    col = walk = None
    stats = None
    for _ in range(reps):
        eng = _fleet_engine(models, c_max, alpha, columnar=True)
        t0 = time.perf_counter()
        col = eng.place_many(tasks)
        col_s = min(col_s, time.perf_counter() - t0)
        stats = eng.columnar_stats

        eng = _fleet_engine(models, c_max, alpha, columnar=False)
        t0 = time.perf_counter()
        walk = eng.place_many(tasks)
        walk_s = min(walk_s, time.perf_counter() - t0)

    # per-task place() loop, timed on a prefix (it is ~two orders slower)
    eng_step = _fleet_engine(models, c_max, alpha)
    queues = {nm: PredictedEdgeQueue() for nm in FLEET_NAMES}
    sub = tasks[:step_n]
    t0 = time.perf_counter()
    step = []
    for t in sub:
        waits = {nm: q.wait_ms(t.arrival_ms) for nm, q in queues.items()}
        d = eng_step.place(t, t.arrival_ms, edge_waits=waits)
        if d.target in queues:
            queues[d.target].push(t.arrival_ms, d.prediction.comp_ms)
        step.append(d)
    step_s = (time.perf_counter() - t0) / max(len(sub), 1) * n

    assert isinstance(col, DecisionBatch), "columnar path did not engage"
    col_targets = col.target_list()
    assert col_targets == [d.target for d in walk], \
        f"{label}: columnar decisions diverged from the walk"
    assert col_targets[:len(step)] == [d.target for d in step], \
        f"{label}: columnar decisions diverged from the step loop"
    vs_walk = walk_s / max(col_s, 1e-12)
    vs_step = step_s / max(col_s, 1e-12)
    print(f"{label:<16} columnar {n / col_s:>10,.0f} t/s  "
          f"walk {n / walk_s:>8,.0f} t/s  step {n / step_s:>7,.0f} t/s  "
          f"vs-walk {vs_walk:5.1f}x  vs-step {vs_step:6.1f}x  "
          f"repairs {stats['repairs']}  walked {stats['walked']}")
    assert vs_walk >= min_speedup, \
        f"{label}: expected >={min_speedup}x vs walk, got {vs_walk:.1f}x"
    emit(f"runtime/place_many_columnar[{label}]", col_s / n * 1e6,
         f"n={n};speedup={vs_walk:.1f}x;vs_step={vs_step:.1f}x")
    emit(f"runtime/place_many_walk[{label}]", walk_s / n * 1e6, f"n={n}")
    emit(f"runtime/place_step[{label}]", step_s / n * 1e6, f"n={n}")
    return vs_walk


def run_decision(emit, n: int | None = None, min_speedup: float = 10.0,
                 mixed_min_speedup: float = 1.5):
    if n is None:
        n = 20_000 if common.REDUCED else 100_000
    banner(f"bench_runtime/decision — columnar place_many vs walk vs step "
           f"({n} tasks, 3-device fleet)")
    twin, models = fit_app("STT", seed=0, n_inputs=120, configs=CONFIGS)
    tasks = _bursty(twin, n, rate_per_s=3.0, seed=3)
    _warm_model_caches(models, tasks)
    step_n = min(n, 4_000 if common.REDUCED else 10_000)

    # saturated fleet: every decision lands on a device — zero repairs, the
    # speculate-and-repair fast path at full speed (the acceptance bar)
    _decision_case(emit, models, tasks, "fleet-saturated", 0.0, 0.0,
                   min_speedup, step_n)
    # mixed budget: edge/cloud oscillation forces repair segments; the
    # columnar core must still win, with a softer bar (fixed segment-pass
    # overheads only amortize at scale, so tiny --n runs just must not lose)
    _decision_case(emit, models, tasks, "mixed-cloud", 2e-5, 0.0,
                   mixed_min_speedup if n >= 50_000 else min(
                       mixed_min_speedup, 1.0), step_n)


# --------------------------------------------------- 2. end-to-end serve
def _serve_case(emit, twin, models, tasks, label, c_max, alpha, min_speedup,
                reps: int = 3):
    n = len(tasks)

    def runtime(columnar):
        eng = _fleet_engine(models, c_max, alpha, columnar=columnar)
        backend = TwinBackend(twin, seed=11, edge_names=FLEET_NAMES,
                              edge_speed=FLEET_SPEEDS)
        return PlacementRuntime(eng, backend)

    col_s = obj_s = float("inf")
    res_col = res_obj = None
    for _ in range(reps):
        rt = runtime(True)
        t0 = time.perf_counter()
        res_col = rt.serve(tasks)
        col_s = min(col_s, time.perf_counter() - t0)
        rt = runtime(False)
        t0 = time.perf_counter()
        res_obj = rt.serve(tasks)
        obj_s = min(obj_s, time.perf_counter() - t0)

    assert isinstance(res_col.records, RecordBatch)
    identical = (res_col.total_actual_cost == res_obj.total_actual_cost
                 and res_col.avg_actual_latency_ms == res_obj.avg_actual_latency_ms
                 and bool((res_col.records.targets == res_obj.records.targets).all()))
    speedup = obj_s / max(col_s, 1e-12)
    print(f"{label:<16} array-native {n / col_s:>10,.0f} t/s  "
          f"objects {n / obj_s:>8,.0f} t/s  speedup {speedup:5.1f}x  "
          f"identical={identical}")
    assert identical, f"{label}: columnar serve diverged from the object path"
    assert speedup >= min_speedup, \
        f"{label}: expected >={min_speedup}x end-to-end, got {speedup:.1f}x"
    emit(f"runtime/serve_columnar[{label}]", col_s / n * 1e6,
         f"n={n};speedup={speedup:.1f}x")
    emit(f"runtime/serve_objects[{label}]", obj_s / n * 1e6, f"n={n}")


def run_serve(emit, n: int | None = None, min_speedup: float = 5.0,
              mixed_min_speedup: float = 1.5):
    if n is None:
        n = 20_000 if common.REDUCED else 100_000
    banner(f"bench_runtime/serve — array-native serve vs legacy object path "
           f"({n} tasks)")
    twin, models = fit_app("STT", seed=0, n_inputs=120, configs=CONFIGS)
    tasks = _bursty(twin, n, rate_per_s=3.0, seed=3)
    _warm_model_caches(models, tasks)

    # saturated fleet: the acceptance bar — every stage on arrays end-to-end
    _serve_case(emit, twin, models, tasks, "fleet-saturated", 0.0, 0.0,
                min_speedup)
    # edge-first budget: periodic cloud offloads force dense repair segments;
    # the array path must still win, with a softer bar (tiny --n runs just
    # must not lose — fixed pass overheads only amortize at scale)
    _serve_case(emit, twin, models, tasks, "edge-budget", FLEET_C_MAX, 0.01,
                mixed_min_speedup if n >= 50_000 else min(
                    mixed_min_speedup, 1.0))


# ----------------------------------------------------- 2. twin execution
def _twin_exec_case(emit, twin, tasks, targets, label: str, min_speedup: float,
                    reps: int = 3):
    """Best-of-``reps`` wall time per path (standard microbenchmark
    de-noising — each rep uses a fresh backend, so every run does identical
    work from identical state)."""
    n = len(tasks)
    seq_s = vec_s = float("inf")
    outs_seq = batch = None
    for _ in range(reps):
        b_seq = TwinBackend(twin, seed=11, edge_names=FLEET_NAMES,
                            edge_speed=FLEET_SPEEDS)
        t0 = time.perf_counter()
        outs_seq = [b_seq.execute(t, tg, t.arrival_ms)
                    for t, tg in zip(tasks, targets)]
        seq_s = min(seq_s, time.perf_counter() - t0)

        b_vec = TwinBackend(twin, seed=11, edge_names=FLEET_NAMES,
                            edge_speed=FLEET_SPEEDS)
        t0 = time.perf_counter()
        batch = b_vec.execute_many(tasks, targets)
        vec_s = min(vec_s, time.perf_counter() - t0)

    identical = outs_seq == batch.outcomes()
    speedup = seq_s / max(vec_s, 1e-12)
    edge_pct = 100.0 * sum(1 for tg in targets if tg in FLEET_SPEEDS) / n
    print(f"{label:<18} edge {edge_pct:5.1f}%  "
          f"seq {n / seq_s:>9.0f} t/s  vec {n / vec_s:>10.0f} t/s  "
          f"speedup {speedup:5.1f}x  identical={identical}")
    assert identical, f"{label}: vectorized outcomes diverged from execute()"
    assert speedup >= min_speedup, \
        f"{label}: expected >={min_speedup}x, got {speedup:.1f}x"
    emit(f"runtime/execute_seq[{label}]", seq_s / n * 1e6, f"n={n}")
    emit(f"runtime/execute_many[{label}]", vec_s / n * 1e6,
         f"n={n};speedup={speedup:.1f}x")
    return speedup


def run_twin_exec(emit, n: int | None = None, min_speedup: float = 10.0,
                  mixed_min_speedup: float = 3.0):
    if n is None:
        n = 20_000 if common.REDUCED else 100_000
    banner(f"bench_runtime/twin-exec — execute_many vs execute loop ({n} tasks)")
    twin, models = fit_app("STT", seed=0, n_inputs=120, configs=CONFIGS)
    tasks = _bursty(twin, n, rate_per_s=3.0, seed=3)

    def targets_for(c_max):
        eng = DecisionEngine(
            predictor=build_fleet_predictor(models, FLEET_SPEEDS, configs=CONFIGS),
            policy=MinLatencyPolicy(c_max=c_max, alpha=0.01))
        return [d.target for d in eng.place_many(tasks)]

    # saturated fleet: the budget keeps the whole burst load on the devices —
    # the regime the vectorized sampler exists for (and the acceptance bar)
    _twin_exec_case(emit, twin, tasks, targets_for(0.0),
                    "fleet-saturated", min_speedup)
    # mixed split: the cloud container-pool walk is sequential bookkeeping,
    # so the ratio is structurally lower — reported with a soft sanity bar
    _twin_exec_case(emit, twin, tasks, targets_for(2e-5), "mixed-cloud",
                    mixed_min_speedup)


# ------------------------------------------------------------- 3. the fleet
def _fleet_runtime(twin, models, balancer=None, devices=None):
    devices = devices if devices is not None else dict(FLEET_SPEEDS)
    pred = build_fleet_predictor(models, devices, configs=CONFIGS)
    kwargs = {"balancer": balancer} if balancer is not None else {}
    eng = DecisionEngine(predictor=pred,
                         policy=MinLatencyPolicy(c_max=FLEET_C_MAX, alpha=ALPHA),
                         **kwargs)
    backend = TwinBackend(twin, seed=11, edge_names=tuple(devices),
                          edge_speed=devices)
    return PlacementRuntime(eng, backend)


def _single_runtime(twin, models):
    pred = build_predictor(models, configs=CONFIGS)
    eng = DecisionEngine(predictor=pred,
                         policy=MinLatencyPolicy(c_max=FLEET_C_MAX, alpha=ALPHA))
    return PlacementRuntime(eng, TwinBackend(twin, seed=11))


def run_fleet(emit, n: int | None = None):
    if n is None:
        n = 1_500 if common.REDUCED else 4_000
    banner(f"bench_runtime/fleet — 3-device fleet vs single edge, "
           f"skewed arrivals ({n} tasks)")
    twin, models = fit_app("IR", seed=0, n_inputs=150, configs=CONFIGS)
    tasks = _bursty(twin, n)

    lpw = _fleet_runtime(twin, models, LeastPredictedWaitBalancer()).serve(tasks)
    rr = _fleet_runtime(twin, models, RoundRobinBalancer()).serve(tasks)
    single = _single_runtime(twin, models).serve(tasks)

    rows = [("fleet-3 least-wait", lpw), ("fleet-3 round-robin", rr),
            ("single edge", single)]
    print(f"{'configuration':<22} {'mean ms':>9} {'p99 ms':>10} {'edge#':>6}")
    for name, res in rows:
        print(f"{name:<22} {res.avg_actual_latency_ms:>9.0f} "
              f"{res.p99_actual_latency_ms:>10.0f} {res.n_edge:>6d}")
    print("\nleast-wait fleet balance:")
    print(lpw.device_table())

    assert lpw.avg_actual_latency_ms < single.avg_actual_latency_ms, \
        "fleet must beat the single-edge configuration on mean latency"
    assert lpw.avg_actual_latency_ms < rr.avg_actual_latency_ms, \
        "least-predicted-wait must beat round-robin on skewed arrivals"
    emit("runtime/fleet_lpw_mean_us", lpw.avg_actual_latency_ms * 1e3, f"n={n}")
    emit("runtime/fleet_rr_mean_us", rr.avg_actual_latency_ms * 1e3, f"n={n}")
    emit("runtime/single_edge_mean_us", single.avg_actual_latency_ms * 1e3,
         f"n={n}")


# --------------------------------------------- 5. live async overlap (ISSUE 4)
def run_live_async(emit, n: int | None = None, min_speedup: float = 2.0):
    """Wall-clock overlap of the live event-driven driver vs sequential
    dispatch: a saturated 3-device edge fleet (edge-only budget) serving real
    compiled executions whose store leg pays an emulated WAN result-upload
    (real ``time.sleep`` waits — the paper's IoT-upload leg). The async
    driver's per-device workers overlap those waits and the compute; the
    sequential driver pays them back-to-back. Placement is identical on both
    sides, so the ratio is pure execution overlap.
    """
    if n is None:
        n = 60 if common.REDUCED else 120
    banner(f"bench_runtime/async-overlap — live serve_async vs sequential "
           f"({n} tasks, 3-device fleet, WAN-emulated store leg)")
    import os

    if (os.cpu_count() or 1) < 2:
        # single core: compute cannot overlap at all, only the WAN waits can
        # — the 2x acceptance bar is judged on >=2 unthrottled cores
        min_speedup = min(min_speedup, 1.2)

    from repro.configs import smoke_config
    from repro.serving.executors import NetworkProfile, SliceSpec
    from repro.serving.placement import (
        calibrate_catalog,
        llm_workload,
        make_live_runtime,
    )

    cfg = smoke_config("llama3.2-1b").with_updates(
        n_layers=2, d_model=32, d_ff=64, vocab=64, n_heads=2, n_kv_heads=2,
        head_dim=16)
    specs = [SliceSpec("s2", 2, tokens_per_step=4),
             SliceSpec("s8", 8, tokens_per_step=4)]
    t0 = time.perf_counter()
    cat = calibrate_catalog(cfg, specs, n_tasks=6, n_cold=1, seed=0,
                            mean_tokens=16.0)
    calib_s = time.perf_counter() - t0
    # arrivals far above fleet capacity: predicted queues build up, so the
    # least-wait balancer spreads the backlog evenly over all three devices
    tasks = llm_workload(n, rate_per_s=2_000.0, seed=4, mean_tokens=16.0)
    net = NetworkProfile(base_ms=40.0, ms_per_byte=0.01)

    def runtime():
        # c_max=0: every task is edge-feasible only — the saturated fleet
        return make_live_runtime(cat, MinLatencyPolicy(c_max=0.0, alpha=0.0),
                                 t_idl_ms=60_000.0, n_edge_devices=3,
                                 network=net)

    rt_seq = runtime()
    t0 = time.perf_counter()
    res_seq = rt_seq.serve(tasks)
    seq_s = time.perf_counter() - t0

    rt_async = runtime()
    t0 = time.perf_counter()
    res_async = rt_async.serve_async(tasks)
    async_s = time.perf_counter() - t0

    assert res_seq.n == n and res_async.n == n
    assert res_async.n_edge == n, "budget must saturate the edge fleet"
    assert [r.target for r in res_seq.records] \
        == [r.target for r in res_async.records], "placement must be identical"
    speedup = seq_s / max(async_s, 1e-12)
    print(f"calibration {calib_s:5.1f}s   sequential {seq_s:6.2f}s "
          f"({n / seq_s:5.1f} t/s)   async {async_s:6.2f}s "
          f"({n / async_s:5.1f} t/s)   overlap speedup {speedup:4.2f}x   "
          f"cores {os.cpu_count()}")
    print("async fleet balance:")
    print(res_async.device_table())
    assert speedup >= min_speedup, \
        f"live async overlap: expected >={min_speedup}x, got {speedup:.2f}x"
    emit("runtime/live_serve_async[fleet-wan]", async_s / n * 1e6,
         f"n={n};speedup={speedup:.2f}x")
    emit("runtime/live_serve_seq[fleet-wan]", seq_s / n * 1e6, f"n={n}")


# ------------------------------------------------------- 6. the 1M scenario
def run_million(emit, n: int = 1_000_000):
    """The columnar end-to-end scale-out: 1M tasks through decisions AND
    execution without a single per-task Python object on the hot path.
    Previously impractical — the object walk alone took minutes and built
    millions of Prediction/Decision/Record objects."""
    banner(f"bench_runtime/million — columnar serve at {n:,} tasks")
    twin, models = fit_app("STT", seed=0, n_inputs=120, configs=CONFIGS)
    t0 = time.perf_counter()
    tasks = _bursty(twin, n, rate_per_s=3.0, seed=3)
    gen_s = time.perf_counter() - t0
    _warm_model_caches(models, tasks)

    eng = _fleet_engine(models, FLEET_C_MAX, 0.01, columnar=True)
    backend = TwinBackend(twin, seed=11, edge_names=FLEET_NAMES,
                          edge_speed=FLEET_SPEEDS)
    rt = PlacementRuntime(eng, backend)
    t0 = time.perf_counter()
    res = rt.serve(tasks)
    serve_s = time.perf_counter() - t0

    assert res.n == n and isinstance(res.records, RecordBatch)
    assert res.n_edge > 0
    print(f"workload gen {gen_s:6.1f}s   serve {serve_s:6.1f}s "
          f"({n / serve_s:,.0f} tasks/s)   "
          f"decision stats {eng.columnar_stats}")
    print(f"mean latency {res.avg_actual_latency_ms:,.0f} ms   "
          f"p99 {res.p99_actual_latency_ms:,.0f} ms   edge {res.n_edge:,}/{n:,}")
    emit("runtime/serve_1m", serve_s / n * 1e6,
         f"n={n};tasks_per_s={n / serve_s:.0f}")


# --------------------------------------- 7. streaming scale (ISSUE 5)
def _stream_runtime(twin, models, c_max=0.0):
    eng = _fleet_engine(models, c_max, 0.0, columnar=True)
    backend = TwinBackend(twin, seed=11, edge_names=FLEET_NAMES,
                          edge_speed=FLEET_SPEEDS)
    return PlacementRuntime(eng, backend)


def run_streaming(emit, n: int = 10_000_000, n_oneshot: int = 1_000_000,
                  chunk: int = 262_144, min_rel_rate: float = 1.0,
                  smoke: bool = False):
    """``serve_stream`` at scale: constant working set, one-shot throughput.

    Full: 10M tasks streamed as ``TaskChunk``s (vectorized Poisson/STT
    generation — no per-task objects anywhere), ``keep_tasks=False``; the
    peak-RSS delta over the pre-stream baseline must stay under the result
    arena's own footprint plus a fixed working-set allowance — i.e. nowhere
    near the one-shot path's O(n × targets) matrices. Throughput must be ≥
    ``min_rel_rate`` × the one-shot ``serve(batched=True)`` rate measured on
    an ``n_oneshot`` list in the same process (the PR 3 acceptance regime:
    saturated fleet, every decision on a device). Smoke: small n, tracemalloc
    ceiling, relaxed rate floor.
    """
    import resource

    banner(f"bench_runtime/streaming-scale — serve_stream at {n:,} tasks "
           f"(chunk {chunk:,})")
    twin, models = fit_app("STT", seed=0, n_inputs=120, configs=CONFIGS)
    wl = twin.poisson(seed=3)
    # warm model caches + first-touch allocations outside the measured window
    _stream_runtime(twin, models).serve_stream(
        wl.chunks(min(chunk, 65_536), 65_536), chunk_size=chunk,
        keep_tasks=False)

    # the arena's exact per-row footprint, derived from its column spec so
    # the ceiling formula can never silently drift from the implementation
    from repro.core import records as records_mod

    arena_row_bytes = (8 * (len(records_mod._ARENA_F64) + 1)    # + arrivals
                       + 8 * (len(records_mod._ARENA_I64) + 1)  # + task_idx
                       + len(records_mod._ARENA_BOOL))
    if smoke:
        import tracemalloc

        rt = _stream_runtime(twin, models)
        t0 = time.perf_counter()
        res = rt.serve_stream(wl.chunks(n, chunk), chunk_size=chunk,
                              keep_tasks=False, expected_tasks=n)
        stream_s = time.perf_counter() - t0
        # memory pass: tracemalloc taxes allocation, so rate is timed above
        tracemalloc.start()
        _stream_runtime(twin, models).serve_stream(
            twin.poisson(seed=4).chunks(n, chunk), chunk_size=chunk,
            keep_tasks=False, expected_tasks=n)
        peak_mb = tracemalloc.get_traced_memory()[1] / 1e6
        tracemalloc.stop()
        ceiling_mb = n * arena_row_bytes / 1e6 * 1.6 + 250.0
        mem_label = f"tracemalloc peak {peak_mb:.0f} MB (ceiling {ceiling_mb:.0f})"
    else:
        rss0_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0
        rt = _stream_runtime(twin, models)
        t0 = time.perf_counter()
        res = rt.serve_stream(wl.chunks(n, chunk), chunk_size=chunk,
                              keep_tasks=False, expected_tasks=n)
        stream_s = time.perf_counter() - t0
        peak_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0
        ceiling_mb = rss0_mb + n * arena_row_bytes / 1e6 * 1.25 + 700.0
        mem_label = (f"peak RSS {peak_mb:.0f} MB "
                     f"(baseline {rss0_mb:.0f}, ceiling {ceiling_mb:.0f})")
    assert res.n == n and res.n_edge == n, "budget must saturate the fleet"
    assert len(res.records.tasks) == 0  # constant-memory result
    rate_stream = n / stream_s

    # one-shot baseline AFTER the stream so its (bigger) footprint cannot
    # pollute the streaming RSS window
    tasks = wl.generate(n_oneshot)
    rt1 = _stream_runtime(twin, models)
    t0 = time.perf_counter()
    res1 = rt1.serve(tasks, batched=True)
    one_s = time.perf_counter() - t0
    assert res1.n == n_oneshot
    rate_one = n_oneshot / one_s
    rel = rate_stream / rate_one

    print(f"stream {n:,} in {stream_s:6.1f}s  ({rate_stream:,.0f} t/s)  "
          f"{mem_label}")
    print(f"one-shot {n_oneshot:,} in {one_s:6.1f}s  ({rate_one:,.0f} t/s)  "
          f"stream/one-shot rate {rel:4.2f}x   "
          f"stream stats {rt.stream_stats}")
    assert peak_mb <= ceiling_mb, \
        f"streaming memory ceiling exceeded: {peak_mb:.0f} > {ceiling_mb:.0f} MB"
    assert rel >= min_rel_rate, \
        f"streaming must serve at >={min_rel_rate}x the one-shot rate, got {rel:.2f}x"
    emit(f"runtime/serve_stream[{n}]", stream_s / n * 1e6,
         f"n={n};chunk={chunk};speedup={rel:.2f}x;peak_mb={peak_mb:.0f}")
    emit(f"runtime/serve_oneshot[{n_oneshot}]", one_s / n_oneshot * 1e6,
         f"n={n_oneshot}")


# module-level shard context so process-mode factories pickle by name.
# Forked children inherit the parent's fitted models for free; spawn-based
# platforms (macOS/Windows default) re-import this module with an empty dict,
# so the accessor lazily re-fits in the child rather than KeyError-ing.
_SHARD_CTX: dict = {}


def _shard_setup(app):
    if app not in _SHARD_CTX:
        _SHARD_CTX[app] = fit_app(app, seed=0, n_inputs=120, configs=CONFIGS)
    return _SHARD_CTX[app]


def _sharded_runtime(app):
    twin, models = _shard_setup(app)
    pred = build_fleet_predictor(models, dict(FLEET_SPEEDS), configs=CONFIGS)
    eng = DecisionEngine(predictor=pred,
                         policy=MinLatencyPolicy(c_max=0.0, alpha=0.0))
    return PlacementRuntime(eng, TwinBackend(
        twin, seed=7, edge_names=FLEET_NAMES, edge_speed=FLEET_SPEEDS))


def _sharded_workload(app, n, chunk):
    return _shard_setup(app)[0].poisson(seed=3).chunks(n, chunk)


def run_sharded(emit, n_per_app: int = 500_000, chunk: int = 65_536,
                min_speedup: float = 2.0):
    """``serve_sharded``: the EdgeBench-style IR+FD+STT mix as parallel
    shards — each with its own Predictor, budget, and fleet partition.

    Per-record parity across sequential / thread / process modes is the hard
    assertion (shards share no state, so scheduling must not perturb one
    draw). The ≥2x wall-clock floor over sequential per-app serves is
    asserted on ≥ 4 cores; CPU-bound shards cannot physically beat ~1x on
    the 2-core class (measured and reported, never asserted there).
    """
    import functools
    import os

    from repro.core.multiapp import AppShard, ShardedRuntime

    apps = ("IR", "FD", "STT")
    banner(f"bench_runtime/sharded — {'+'.join(apps)} parallel shards "
           f"({n_per_app:,} tasks/app)")
    for app in apps:
        _shard_setup(app)

    def shards():
        return [AppShard(name=app,
                         runtime=functools.partial(_sharded_runtime, app),
                         workload=functools.partial(_sharded_workload, app,
                                                    n_per_app, chunk),
                         chunk_size=chunk)
                for app in apps]

    # warm EVERY shard's one-time caches (GBRT step tables are process-wide
    # and fork-inherited, so leaving FD/STT cold would bill their derivation
    # to the sequential baseline only and inflate the measured speedup)
    warm = [AppShard(name=app,
                     runtime=functools.partial(_sharded_runtime, app),
                     workload=functools.partial(_sharded_workload, app,
                                                4_096, chunk),
                     chunk_size=chunk)
            for app in apps]
    ShardedRuntime(warm).serve(parallel=False)
    seq = ShardedRuntime(shards()).serve(parallel=False)
    thr = ShardedRuntime(shards()).serve(parallel=True)
    proc = ShardedRuntime(shards()).serve(parallel=True, use_processes=True)

    for app in apps:
        a, b, c = (m.results[app].records for m in (seq, thr, proc))
        assert np.array_equal(a.actual_latency_ms, b.actual_latency_ms) \
            and np.array_equal(a.actual_latency_ms, c.actual_latency_ms) \
            and a.target_codes.tolist() == b.target_codes.tolist() \
            == c.target_codes.tolist(), \
            f"{app}: sharded results diverged across execution modes"

    thr_x = seq.elapsed_s / max(thr.elapsed_s, 1e-9)
    proc_x = seq.elapsed_s / max(proc.elapsed_s, 1e-9)
    cores = os.cpu_count() or 1
    print(f"sequential {seq.elapsed_s:6.2f}s   threads {thr.elapsed_s:6.2f}s "
          f"({thr_x:4.2f}x)   processes {proc.elapsed_s:6.2f}s "
          f"({proc_x:4.2f}x)   cores {cores}")
    print(thr.table())
    best = max(thr_x, proc_x)
    if cores >= 4:
        assert best >= min_speedup, \
            f"sharded overlap: expected >={min_speedup}x on {cores} cores, " \
            f"got {best:.2f}x"
    else:
        print(f"(floor not asserted: {cores} cores cannot overlap 3 "
              f"CPU-bound shards — parity checks above are the gate)")
    emit("runtime/sharded_thread[3app]", thr.elapsed_s / (3 * n_per_app) * 1e6,
         f"n={3 * n_per_app};speedup={thr_x:.2f}x;cores={cores}")
    emit("runtime/sharded_process[3app]",
         proc.elapsed_s / (3 * n_per_app) * 1e6,
         f"n={3 * n_per_app};speedup={proc_x:.2f}x;cores={cores}")
    emit("runtime/sharded_seq[3app]", seq.elapsed_s / (3 * n_per_app) * 1e6,
         f"n={3 * n_per_app}")


# --------------------------- 8. trace replay + capacity planner (ISSUE 6)
def _record_trace(wl, n: int, chunk: int, app: str):
    """Record a workload's chunk stream into a ``Trace`` (columns only —
    the bench never materializes per-task objects)."""
    from repro.trace import Trace

    cols = ([], [], [])
    for c in wl.chunks(n, chunk):
        cols[0].append(c.arrival_ms)
        cols[1].append(c.size)
        cols[2].append(c.bytes)
    return Trace.from_arrays(*(np.concatenate(x) for x in cols),
                             app_names=(app,))


def run_trace_planner(emit, n: int = 50_000, chunk: int = 16_384,
                      max_rel: float = 1.2, smoke: bool = False):
    """Trace replay rate + what-if planner search (ISSUE 6).

    Replay floor: streaming a recorded trace through ``serve_stream``
    (``TraceWorkload`` chunk views) must land within ``max_rel``× the wall
    time of the equivalent in-memory stream (the workload generating the
    same chunks on the fly) — replay slices arrays instead of sampling, so
    it has no excuse to be slower; per-record parity between the two runs is
    asserted. Planner: an 8-candidate successive-halving search (fleet sizes
    1–4 × edge-only/cloud-budget policies) over the same trace; the winner
    must meet the SLO, be the cheapest config that does, and be verified on
    the full trace.
    """
    from repro.planner import Candidate, Planner, PolicySpec, SLO
    from repro.trace import TraceWorkload

    banner(f"bench_runtime/trace-planner — replay + what-if search "
           f"({n:,}-task STT trace)")
    twin, models = _shard_setup("STT")
    wl = twin.poisson(seed=3)
    trace = _record_trace(wl, n, chunk, "STT")
    reps = 1 if smoke else 2

    # warm caches outside the measured window
    _stream_runtime(twin, models).serve_stream(wl.chunks(4_096, chunk),
                                               chunk_size=chunk)
    mem_s = rep_s = float("inf")
    res_mem = res_rep = None
    for _ in range(reps):
        rt = _stream_runtime(twin, models)
        t0 = time.perf_counter()
        res_mem = rt.serve_stream(wl.chunks(n, chunk), chunk_size=chunk)
        mem_s = min(mem_s, time.perf_counter() - t0)

        rt = _stream_runtime(twin, models)
        t0 = time.perf_counter()
        res_rep = rt.serve_stream(TraceWorkload(trace).chunks(chunk_size=chunk),
                                  chunk_size=chunk)
        rep_s = min(rep_s, time.perf_counter() - t0)

    a, b = res_mem.records, res_rep.records
    identical = (a.target_codes.tolist() == b.target_codes.tolist()
                 and np.array_equal(a.actual_latency_ms, b.actual_latency_ms)
                 and np.array_equal(a.actual_cost, b.actual_cost))
    rel = rep_s / max(mem_s, 1e-12)
    print(f"in-memory {n / mem_s:>9,.0f} t/s   replay {n / rep_s:>9,.0f} t/s "
          f"  rel {rel:4.2f}x (floor {max_rel:.1f}x)   identical={identical}")
    assert identical, "trace replay diverged from the in-memory stream"
    assert rel <= max_rel, \
        f"trace replay {rel:.2f}x slower than in-memory (floor {max_rel}x)"
    emit(f"trace/replay_stream[{n}]", rep_s / n * 1e6,
         f"n={n};chunk={chunk};speedup={mem_s / max(rep_s, 1e-12):.2f}x")

    # ---- the 8-candidate what-if search
    edge_only = PolicySpec(kind="min_latency", c_max=0.0)
    mixed = PolicySpec(kind="min_latency", c_max=C_MAX, alpha=ALPHA)
    cands = [Candidate.make(f"fleet-{k}-{tag}", k, policy=pol,
                            cloud_configs=CONFIGS, chunk_size=chunk,
                            device_rate_per_hour=0.05)
             for k in (1, 2, 3, 4)
             for tag, pol in (("edge", edge_only), ("mixed", mixed))]
    slo = SLO(latency_ms=40_000.0, target=0.95)
    planner = Planner(trace, slo, fit_seed=0, n_inputs=120,
                      fit_configs=CONFIGS)
    t0 = time.perf_counter()
    res = planner.plan(cands, strategy="halving", rungs=3, min_rung_n=2_048)
    plan_s = time.perf_counter() - t0

    print(res.table())
    print(f"planner: {len(cands)} candidates, {res.replayed_tasks:,} tasks "
          f"replayed ({res.mode}) in {plan_s:.1f}s   best "
          f"{res.best.candidate.name}")
    assert res.best.meets_slo, "no candidate met the SLO on the bench fixture"
    assert res.best.n == trace.n, "winner must be verified on the full trace"
    meeting = [s for s in res.scores if s.meets_slo]
    assert res.best.total_cost == min(s.total_cost for s in meeting), \
        "planner returned a non-cheapest SLO-meeting candidate"
    emit(f"trace/planner_search[{len(cands)}cand]",
         plan_s / max(res.replayed_tasks, 1) * 1e6,
         f"n={res.replayed_tasks};candidates={len(cands)};"
         f"best={res.best.candidate.name}")


# --------------------------------------------- 9. device core (ISSUE 7)
def run_jax_core(emit, n: int = 1_000_000, chunk: int = 65_536,
                 min_speedup: float = 2.0, smoke: bool = False):
    """Device-resident predict→place (ISSUE 7): jax core vs numpy oracle.

    Full: a steady Poisson STT stream (containers stay warm — the container
    pool and the fixed-point pass count sit at their steady state) served
    end-to-end with ``array_backend="jax"`` vs ``"numpy"``; decisions must be
    identical, and on an accelerator the device core must clear
    ``min_speedup``× the numpy rate (report-only on CPU, where XLA's
    sequential scans lose to numpy's cumsum segments — the same trace is the
    fast path on TPU). Smoke: bit-parity of ``"jax_interpret"`` against the
    oracle per record, decision-equality of compiled ``"jax"``, and the
    no-retrace gate — a second same-shape stream must reuse every jit cache
    entry after the warmup serve.

    Both variants finish with the SCAN_MODE audit: "seq" and "assoc" are
    timed on compiled streams (warmup + compile-free rerun each) and the
    winner is compared to what ``resolve_scan_mode`` picks for this backend
    under ``SCAN_MODE="auto"`` — asserted at full size on accelerators,
    report-only on CPU (timing noise at smoke sizes makes the "winner" a
    coin flip there; the table itself was derived at full size).
    """
    import jax as jax_mod

    from repro.core import jax_core

    backend_name = jax_mod.default_backend()
    on_accel = backend_name != "cpu"
    banner(f"bench_runtime/jax-core — device-resident placement at {n:,} "
           f"tasks (chunk {chunk:,}, backend {backend_name})")
    twin, models = fit_app("STT", seed=0, n_inputs=120, configs=CONFIGS)

    def _serve(backend, n_tasks, seed=3):
        rt = _stream_runtime(twin, models, c_max=FLEET_C_MAX)
        src = twin.poisson(seed=seed)
        t0 = time.perf_counter()
        res = rt.serve_stream(src.chunks(n_tasks, chunk), chunk_size=chunk,
                              array_backend=backend)
        return res, time.perf_counter() - t0, rt

    if smoke:
        n = min(n, 3_000)
        # ---- parity gate: interpret vs oracle, bit-identical per record
        ref, _, _ = _serve("numpy", n)
        it, _, rt_it = _serve("jax_interpret", n)
        cols = ("predicted_latency_ms", "predicted_cost", "actual_latency_ms",
                "actual_cost", "allowed_cost", "completion_ms",
                "queue_wait_ms", "predicted_cold", "actual_cold", "feasible")
        bit_ok = (ref.records.target_codes.tolist()
                  == it.records.target_codes.tolist()
                  and all(np.array_equal(getattr(ref.records, c),
                                         getattr(it.records, c))
                          for c in cols))
        assert bit_ok, "jax_interpret diverged from the numpy oracle"
        assert rt_it.engine.jax_stats["interpret"]
        print(f"interpret parity  : {n:,} records bit-identical "
              f"(stats {rt_it.engine.jax_stats})")

    # first serve compiles and grows the container-pool cap to steady state;
    # the second stream reuses the SAME engine (and so the same jit caches):
    # same chunk shapes ⇒ it must not retrace, and its time is compile-free
    comp, jax_s, rt_jx = _serve("jax", n)
    core = jax_core.core_for(rt_jx.engine)
    stats_before = core.compile_stats()
    t0 = time.perf_counter()
    rt_jx.serve_stream(twin.poisson(seed=5).chunks(n, chunk),
                       chunk_size=chunk, array_backend="jax")
    jax2_s = time.perf_counter() - t0
    assert jax_core.core_for(rt_jx.engine) is core
    stats_after = core.compile_stats()
    assert stats_after == stats_before, \
        f"jax core retraced on a same-shape stream: " \
        f"{stats_before} -> {stats_after}"
    jax_s = min(jax_s, jax2_s)

    ref, np_s, _ = _serve("numpy", n)
    assert (ref.records.target_codes.tolist()
            == comp.records.target_codes.tolist()), \
        "compiled jax decisions diverged from the numpy oracle"
    speedup = np_s / max(jax_s, 1e-12)
    bar = f"(floor {min_speedup:.1f}x)" if on_accel else "(report-only on CPU)"
    print(f"numpy {n / np_s:>9,.0f} t/s   jax[{backend_name}] "
          f"{n / jax_s:>9,.0f} t/s   speedup {speedup:4.2f}x {bar}   "
          f"no-retrace OK {stats_after}")
    if on_accel:
        assert speedup >= min_speedup, \
            f"device core {speedup:.2f}x below the {min_speedup}x floor " \
            f"on {backend_name}"
    emit(f"runtime/jax_core[{n}]", jax_s / n * 1e6,
         f"n={n};chunk={chunk};backend={backend_name};"
         f"speedup={speedup:.2f}x;accel={int(on_accel)}")

    # ---- SCAN_MODE audit (ISSUE 9): time the sequential lax.scan folds vs
    # the reassociated max-plus/cumsum forms and check the "auto" table
    # against the measurement. SCAN_MODE is part of the engine key, so each
    # mode gets its own core: warm it up, then time a compile-free rerun on
    # the SAME runtime (the same jit caches).
    n_scan = n if smoke else max(chunk, n // 4)
    mode_s = {}
    prior = jax_core.SCAN_MODE
    try:
        for sm in ("seq", "assoc"):
            jax_core.SCAN_MODE = sm
            rt_m = _stream_runtime(twin, models, c_max=FLEET_C_MAX)
            rt_m.serve_stream(twin.poisson(seed=3).chunks(n_scan, chunk),
                              chunk_size=chunk, array_backend="jax")
            t0 = time.perf_counter()
            rt_m.serve_stream(twin.poisson(seed=5).chunks(n_scan, chunk),
                              chunk_size=chunk, array_backend="jax")
            mode_s[sm] = time.perf_counter() - t0
    finally:
        jax_core.SCAN_MODE = prior
    winner = min(mode_s, key=mode_s.get)
    auto = jax_core.resolve_scan_mode(backend_name)
    gate = "asserted" if on_accel and not smoke else "report-only"
    print(f"scan-mode audit   seq {n_scan / mode_s['seq']:>9,.0f} t/s   "
          f"assoc {n_scan / mode_s['assoc']:>9,.0f} t/s   winner={winner}   "
          f"auto[{backend_name}]={auto} ({gate})")
    if on_accel and not smoke:
        assert auto == winner, \
            f"SCAN_MODE auto table picks {auto!r} on {backend_name} but " \
            f"the measurement favors {winner!r} — update jax_core._AUTO_SCAN"
    emit(f"runtime/scan_mode[{n_scan}]", mode_s[auto] / n_scan * 1e6,
         f"n={n_scan};seq_s={mode_s['seq']:.3f};"
         f"assoc_s={mode_s['assoc']:.3f};winner={winner};auto={auto};"
         f"backend={backend_name}")


# --------------------------------------------------- 10. chaos (ISSUE 8)
def run_chaos(emit, n: int | None = None, max_overhead: float = 0.03,
              min_top_slo: float = 0.99, smoke: bool = False, reps: int = 3):
    """Chaos twin (ISSUE 8): faults-off overhead floor + degradation smoke.

    Overhead: a runtime with retry + breaker + admission configured over an
    EMPTY ``FaultSpec`` must serve the saturated-fleet workload bit-identically
    per record to the plain runtime AND within ``max_overhead`` of its serve
    rate (the failure-aware round 0 issues the identical ``execute_many``
    call; everything else is gated fast paths). The 3% bar is judged at full
    size — smoke relaxes it (shared CI runners throttle) but keeps the parity
    gate at full strength. Degradation: one of the three devices down for the
    middle 30% of the run, 15% transient errors on one cloud config, with
    retry/failover/breaker/admission on — the top (non-sheddable) SLO tier
    must still make ``min_top_slo`` attainment, riding on failover and
    batch-tier shedding.
    """
    from repro.core.faults import (
        AdmissionPolicy,
        CircuitBreaker,
        FaultSpec,
        OutageWindow,
        RetryPolicy,
        SLOTier,
        TransientErrors,
    )

    if n is None:
        n = 20_000 if common.REDUCED else 100_000
    banner(f"bench_runtime/chaos — faults-off overhead + degradation "
           f"({n:,} tasks)")
    twin, models = fit_app("STT", seed=0, n_inputs=120, configs=CONFIGS)
    tasks = _bursty(twin, n, rate_per_s=3.0, seed=3)
    for t in tasks:
        t.tier = 0 if t.idx % 4 else 1      # 75% interactive, 25% batch
    _warm_model_caches(models, tasks)

    def runtime(faults=None, **knobs):
        eng = _fleet_engine(models, C_MAX, ALPHA)
        backend = TwinBackend(twin, seed=11, edge_names=FLEET_NAMES,
                              edge_speed=FLEET_SPEEDS, faults=faults)
        return PlacementRuntime(eng, backend, **knobs)

    # ---- faults-off overhead: empty spec + full failure machinery armed.
    # Stage-timed (placement and execution separately, best-of-reps each,
    # interleaved): the placement stage is identical code on both sides and
    # its run-to-run variance (CIL churn, GC) is several times the 3% bar,
    # so timing whole serves best-of-reps would measure noise, not the
    # failure-aware execute path this section gates.
    knobs = dict(retry=RetryPolicy(), breaker=CircuitBreaker(),
                 admission=AdmissionPolicy(tiers=(SLOTier(1e12),)))
    stage_s = {"plain": [float("inf")] * 2, "fa": [float("inf")] * 2}
    recs = {}
    for _ in range(reps):
        for tag, rt in (("plain", runtime()),
                        ("fa", runtime(faults=FaultSpec(), **knobs))):
            rt._snapshot_horizons()
            t0 = time.perf_counter()
            d = rt.engine.place_many(tasks, edge_queues=rt.edge_queues)
            stage_s[tag][0] = min(stage_s[tag][0], time.perf_counter() - t0)
            t0 = time.perf_counter()
            recs[tag] = rt._execute_decisions(tasks, d)
            stage_s[tag][1] = min(stage_s[tag][1], time.perf_counter() - t0)
    identical = all(
        np.array_equal(getattr(recs["plain"], c), getattr(recs["fa"], c))
        for c in ("actual_latency_ms", "actual_cost", "completion_ms",
                  "target_codes", "attempts"))
    plain_s, fa_s = (sum(stage_s[t]) for t in ("plain", "fa"))
    overhead = fa_s / max(plain_s, 1e-12) - 1.0
    print(f"faults-off        plain {n / plain_s:>10,.0f} t/s  "
          f"failure-aware {n / fa_s:>10,.0f} t/s  overhead {overhead:+6.1%}  "
          f"(exec stage {stage_s['plain'][1]:.3f}s -> "
          f"{stage_s['fa'][1]:.3f}s)  identical={identical}")
    assert identical, "empty FaultSpec diverged from the plain serve path"
    assert overhead <= max_overhead, \
        f"faults-off overhead {overhead:+.1%} above the " \
        f"{max_overhead:.0%} floor"
    emit(f"runtime/chaos_off[{n}]", fa_s / n * 1e6,
         f"n={n};overhead={overhead:+.3f}")

    # ---- degradation: edge1 down for the middle 30%, one flaky cloud config
    span = tasks[-1].arrival_ms
    top_slo_ms = 3.0 * float(np.percentile(
        recs["plain"].actual_latency_ms, 99))
    spec = FaultSpec(seed=7,
                     outages=[OutageWindow("edge1", 0.35 * span, 0.65 * span)],
                     transient=[TransientErrors("1792", 0.15)])
    rt = runtime(
        faults=spec, retry=RetryPolicy(max_attempts=4, backoff_ms=50.0),
        breaker=CircuitBreaker(threshold=3, probation_ms=30_000.0),
        admission=AdmissionPolicy(tiers=(
            SLOTier(top_slo_ms, sheddable=False),
            SLOTier(float(np.percentile(
                recs["plain"].actual_latency_ms, 50))))))
    t0 = time.perf_counter()
    res = rt.serve(tasks)
    chaos_s = time.perf_counter() - t0
    top = res.slo_attainment(top_slo_ms, tier=0)
    print(f"degraded (1/3 down 30%)  {n / chaos_s:>10,.0f} t/s  "
          f"top-tier SLO {top:6.2%} (floor {min_top_slo:.0%})  "
          f"retried {res.n_retried:,}  failed {res.n_failed:,}  "
          f"shed {res.n_shed:,}  breaker opens {rt.health.n_opens}")
    assert res.n_retried > 0, "the fault schedule never fired"
    assert top >= min_top_slo, \
        f"top-tier SLO attainment {top:.2%} under outage below the " \
        f"{min_top_slo:.0%} floor"
    emit(f"runtime/chaos_degraded[{n}]", chaos_s / n * 1e6,
         f"n={n};top_slo={top:.4f};retried={res.n_retried};"
         f"shed={res.n_shed};opens={rt.health.n_opens}")


# ----------------------------------------------- 11. residency (ISSUE 9)
def run_residency(emit, n: int = 1_000_000, chunk: int = 65_536,
                  min_rel_rate: float = 1.2, smoke: bool = False):
    """Persistent device residency (ISSUE 9): sync counts + resident rate.

    Steady stream: a Poisson STT stream served compiled (``"jax"``) with
    residency on keeps CIL pools / surplus bank / edge horizons device-side
    across chunks. The stream must place EVERY chunk resident — zero host
    commits at chunk boundaries, zero fallback syncs, at most the single
    stream-end materialization — while staying decision-identical to the
    PR 7 per-chunk path (``device_residency=False`` on an identical engine,
    which commits host state once per chunk). On an accelerator the resident
    stream must clear ``min_rel_rate``× the per-chunk rate (report-only on
    CPU, where the host commit is cheap relative to XLA's scan overhead).

    Fallback exits: a hedged chunk mid-stream is ineligible for the device
    core, so residency must exit through exactly ONE fallback sync (the host
    walk sees canonical state) and re-enter afterwards with state intact —
    the sync budget is per fallback EXIT, never per chunk.

    Smoke: the same counter + parity gates at small n; the rate floor is
    judged at full size on an accelerator only.
    """
    import jax as jax_mod

    from repro.core import jax_core
    from repro.core.decision import HedgedPolicy

    backend_name = jax_mod.default_backend()
    on_accel = backend_name != "cpu"
    if smoke:
        n = min(n, 3_000)
    banner(f"bench_runtime/residency — persistent device state at {n:,} "
           f"tasks (chunk {chunk:,}, backend {backend_name})")
    twin, models = fit_app("STT", seed=0, n_inputs=120, configs=CONFIGS)

    def _serve(rt, n_tasks, seed, **kw):
        if rt is None:
            rt = _stream_runtime(twin, models, c_max=FLEET_C_MAX)
        t0 = time.perf_counter()
        res = rt.serve_stream(twin.poisson(seed=seed).chunks(n_tasks, chunk),
                              chunk_size=chunk, array_backend="jax", **kw)
        return res, time.perf_counter() - t0, rt

    # ---- steady resident stream: the warmup serve compiles and grows the
    # container-pool cap to steady state; the rerun on the SAME engine is
    # compile-free and is what gets timed and counter-audited
    _, _, rt_res = _serve(None, n, 3)
    res_r, res_s, _ = _serve(rt_res, n, 5)
    chunks = rt_res.stream_stats["chunks"]
    r = rt_res.stream_stats["residency"]
    assert r["enabled"] and r["resident_chunks"] == chunks
    assert r["chunk_commits"] == 0, \
        "resident stream committed host state at a chunk boundary"
    assert r["fallback_syncs"] == 0, "steady stream took a fallback exit"
    assert r["state_syncs"] <= 1, \
        f"steady resident stream materialized {r['state_syncs']}x " \
        f"(budget: 1, the stream-end sync)"

    # ---- PR 7 per-chunk baseline: identical engine shape with residency
    # off — one host commit per chunk, decisions must not change
    _, _, rt_pc = _serve(None, n, 3, device_residency=False)
    res_p, pc_s, _ = _serve(rt_pc, n, 5, device_residency=False)
    rp = rt_pc.stream_stats["residency"]
    assert not rp["enabled"] and rp["chunk_commits"] == chunks
    assert (res_r.records.target_codes.tolist()
            == res_p.records.target_codes.tolist()), \
        "resident decisions diverged from the per-chunk path"
    rel = pc_s / max(res_s, 1e-12)
    bar = (f"(floor {min_rel_rate:.1f}x)" if on_accel and not smoke
           else "(report-only)")
    print(f"per-chunk {n / pc_s:>9,.0f} t/s   resident {n / res_s:>9,.0f} "
          f"t/s   rel {rel:4.2f}x {bar}   syncs/stream {r['state_syncs']}   "
          f"prefetched {r['prefetched']}")
    if on_accel and not smoke:
        assert rel >= min_rel_rate, \
            f"resident stream {rel:.2f}x below the {min_rel_rate}x floor " \
            f"on {backend_name}"

    # ---- fallback exits cost ONE sync each: chunk 2 of 4 runs under a
    # hedged policy (core-ineligible → host walk), chunks 0-1 and 3 stay
    # resident. Prefetch off: the transfer thread would fire the generator's
    # policy-swap side effect a chunk early.
    tasks = _bursty(twin, 2_000, rate_per_s=4.0, seed=7)

    def hedged_chunks(rt):
        orig = rt.engine.policy
        hedged = HedgedPolicy(MinLatencyPolicy(c_max=FLEET_C_MAX, alpha=0.0),
                              hedge_threshold_ms=50.0)
        for i in range(4):
            rt.engine.policy = hedged if i == 2 else orig
            yield tasks[i * 500:(i + 1) * 500]

    ref_rt = _stream_runtime(twin, models, c_max=FLEET_C_MAX)
    ref = ref_rt.serve_stream(hedged_chunks(ref_rt), chunk_size=500)
    rt_fb = _stream_runtime(twin, models, c_max=FLEET_C_MAX)
    res_fb = rt_fb.serve_stream(hedged_chunks(rt_fb), chunk_size=500,
                                array_backend="jax", prefetch=False)
    rf = rt_fb.stream_stats["residency"]
    assert (res_fb.records.target_codes.tolist()
            == ref.records.target_codes.tolist()), \
        "fallback/re-entry stream diverged from the numpy oracle"
    assert rf["fallback_syncs"] == 1, \
        f"one hedged chunk cost {rf['fallback_syncs']} fallback syncs"
    assert rf["state_syncs"] == 2     # the fallback exit + the stream end
    assert rf["resident_chunks"] == 3 and rf["chunk_commits"] == 0
    print(f"fallback exit     1 hedged chunk of 4 -> "
          f"{rf['fallback_syncs']} fallback sync / {rf['state_syncs']} total"
          f"   residency re-entered ({rf['resident_chunks']}/4 resident)")
    emit(f"runtime/residency[{n}]", res_s / n * 1e6,
         f"n={n};chunk={chunk};backend={backend_name};rel_rate={rel:.2f}x;"
         f"state_syncs={r['state_syncs']};prefetched={r['prefetched']};"
         f"accel={int(on_accel)}")


# ------------------------------------------------ 12. overload (ISSUE 10)
def run_overload(emit, n: int | None = None, max_overhead: float = 0.03,
                 min_top_slo: float = 0.99, smoke: bool = False,
                 reps: int = 3):
    """Overload survival (ISSUE 10): prewarm + reclamation + idle floor.

    Prewarm: a 20x MMPP burst over the 3-device fleet. The reactive baseline
    eats the cold-start storm at each burst front (its warm pool matches the
    quiet-phase rate); the predictive pre-warmer must forecast the regime
    switches and spawn keep-alive containers ahead of the fronts, strictly
    cutting the cold-start count.

    Reclamation: sustained bursts saturating ONE device of the fleet (the
    burst lands on a single hot edge; on a uniformly saturated fleet a
    preempted task's re-placement just moves the pressure next door, so the
    single-device case is where reclamation has physics to exploit — the
    masked re-placement forces victims to cloud). Lower-tier work already
    placed on the hot device is preempted and demoted; the top (non-
    sheddable) tier must clear ``min_top_slo`` attainment that the
    reclamation-off serve visibly misses, with real downgrades (not sheds).

    Policies-off floor: stage-timed best-of-reps like ``run_chaos`` — a
    runtime with BOTH policies armed but never triggering (forecaster fold
    runs every chunk, pressure test runs every batch) must stay bit-
    identical per record to the plain runtime and within ``max_overhead``
    of its rate. Judged at full size; smoke relaxes the bar (shared CI
    runners throttle) but keeps parity at full strength.
    """
    from repro.core.decision import MinCostPolicy
    from repro.core.faults import SLOTier
    from repro.core.overload import PrewarmPolicy, ReclamationPolicy

    if n is None:
        n = 20_000 if common.REDUCED else 100_000
    banner(f"bench_runtime/overload — prewarm + reclamation + idle floor "
           f"({n:,} tasks)")
    twin, models = fit_app("FD", seed=0, n_inputs=120, configs=CONFIGS)

    def runtime(policy=None, fleet=FLEET_SPEEDS, **knobs):
        pred = build_fleet_predictor(models, dict(fleet), configs=CONFIGS)
        eng = DecisionEngine(predictor=pred, policy=policy or MinLatencyPolicy(
            c_max=C_MAX, alpha=ALPHA))
        backend = TwinBackend(twin, seed=11, edge_names=tuple(fleet),
                              edge_speed=dict(fleet))
        return PlacementRuntime(eng, backend, **knobs)

    # ---- prewarm: 20x bursts, reactive vs predictive over the full fleet
    n_pw = 5_000
    burst = BurstyWorkload(rate_per_s=2.0, size_sampler=twin.sample_input,
                           burst_multiplier=20.0, mean_quiet_s=20.0,
                           mean_burst_s=5.0, seed=3).generate(n_pw)
    reactive = runtime().serve(burst)
    rt_pw = runtime(prewarm=PrewarmPolicy(count=4))
    t0 = time.perf_counter()
    warmed = rt_pw.serve(burst)
    pw_s = time.perf_counter() - t0
    cold_re = int(reactive.records.actual_cold.sum())
    cold_pw = int(warmed.records.actual_cold.sum())
    print(f"prewarm           reactive {cold_re:>4d} cold starts  "
          f"predictive {cold_pw:>4d}  "
          f"({rt_pw.overload.forecaster.n_triggers} bursts forecast, "
          f"{len(rt_pw.overload.prewarm_log)} containers spawned)")
    assert rt_pw.overload.forecaster.n_triggers > 0, \
        "the burst forecaster never fired on a 20x MMPP workload"
    assert cold_pw < cold_re, \
        f"predictive prewarm ({cold_pw} cold starts) must beat the " \
        f"reactive baseline ({cold_re})"
    emit(f"runtime/overload_prewarm[{n_pw}]", pw_s / n_pw * 1e6,
         f"n={n_pw};cold_reactive={cold_re};cold_prewarm={cold_pw};"
         f"triggers={rt_pw.overload.forecaster.n_triggers}")

    # ---- reclamation: bursts saturating one hot device, tiered 10/45/45
    n_rc, chunk, top_slo_ms = 4_000, 64, 180_000.0
    hot = {"edge0": 1.0}
    tasks = BurstyWorkload(rate_per_s=0.05, size_sampler=twin.sample_input,
                           burst_multiplier=5.0, mean_quiet_s=150.0,
                           mean_burst_s=30.0, seed=3).generate(n_rc)
    for i, t in enumerate(tasks):
        t.tier = 0 if i % 10 == 0 else (1 if i % 2 else 2)
    recl = ReclamationPolicy(
        tiers=(SLOTier(top_slo_ms, sheddable=False),
               SLOTier(3_000.0), SLOTier(2_500.0)),
        shares=(8.0, 1.0, 1.0), headroom=0.1)
    # deadline 1e9 keeps placement all-edge: the policy itself must not
    # relieve the device, only reclamation may
    off = runtime(MinCostPolicy(deadline_ms=1e9), hot).serve_stream(
        tasks, chunk_size=chunk)
    rt_rc = runtime(MinCostPolicy(deadline_ms=1e9), hot, reclamation=recl)
    t0 = time.perf_counter()
    on = rt_rc.serve_stream(tasks, chunk_size=chunk)
    rc_s = time.perf_counter() - t0
    slo_off = off.slo_attainment(top_slo_ms, tier=0)
    slo_on = on.slo_attainment(top_slo_ms, tier=0)
    moved = sum(1 for e in rt_rc.overload.reclaim_log if e[6])
    print(f"reclamation       top-tier SLO {slo_off:6.2%} -> {slo_on:6.2%}  "
          f"({len(rt_rc.overload.reclaim_log)} preempted, {moved} moved to "
          f"cloud, {on.n_downgraded} demoted, shed {on.n_shed})")
    assert slo_on >= min_top_slo, \
        f"top-tier SLO {slo_on:.2%} under reclamation below the " \
        f"{min_top_slo:.0%} floor"
    assert slo_on > slo_off, \
        "reclamation must visibly improve top-tier attainment"
    assert on.n_downgraded > 0 and moved > 0, \
        "reclamation must demote real (moved) lower-tier work, not shed it"
    emit(f"runtime/overload_reclaim[{n_rc}]", rc_s / n_rc * 1e6,
         f"n={n_rc};slo_off={slo_off:.4f};slo_on={slo_on:.4f};"
         f"preempted={len(rt_rc.overload.reclaim_log)};"
         f"downgraded={on.n_downgraded}")

    # ---- policies-off floor: both policies armed but idle. Stage-timed
    # best-of-reps (see run_chaos: whole-serve timing would measure
    # placement-stage noise, not the armed hooks this gates). The stages
    # mirror serve(batched=True) exactly, hooks included.
    idle_pw = PrewarmPolicy(min_gaps=10**9)           # fold runs, no trigger
    idle_rc = ReclamationPolicy(tiers=(SLOTier(1e15, sheddable=False),
                                       SLOTier(1e12)), shares=(1.0, 1.0))
    tasks = _bursty(twin, n, rate_per_s=3.0, seed=3)
    for t in tasks:
        t.tier = 0 if t.idx % 4 else 1
    _warm_model_caches(models, tasks)
    stage_s = {"plain": [float("inf")] * 2, "armed": [float("inf")] * 2}
    recs = {}
    for _ in range(reps):
        for tag, rt in (("plain", runtime()),
                        ("armed", runtime(prewarm=idle_pw,
                                          reclamation=idle_rc))):
            t0 = time.perf_counter()
            rt._pre_place(tasks)
            rt._snapshot_horizons()
            d = rt.engine.place_many(tasks, edge_queues=rt.edge_queues)
            stage_s[tag][0] = min(stage_s[tag][0], time.perf_counter() - t0)
            t0 = time.perf_counter()
            r = rt._execute_decisions(tasks, d)
            rt._post_execute(r)
            recs[tag] = r
            stage_s[tag][1] = min(stage_s[tag][1], time.perf_counter() - t0)
    identical = all(
        np.array_equal(getattr(recs["plain"], c), getattr(recs["armed"], c))
        for c in ("actual_latency_ms", "actual_cost", "completion_ms",
                  "target_codes", "downgraded"))
    plain_s, armed_s = (sum(stage_s[t]) for t in ("plain", "armed"))
    overhead = armed_s / max(plain_s, 1e-12) - 1.0
    print(f"policies-off      plain {n / plain_s:>10,.0f} t/s  "
          f"armed-idle {n / armed_s:>10,.0f} t/s  overhead {overhead:+6.1%}  "
          f"identical={identical}")
    assert identical, "armed-but-idle policies diverged from the plain serve"
    assert overhead <= max_overhead, \
        f"policies-off overhead {overhead:+.1%} above the " \
        f"{max_overhead:.0%} floor"
    emit(f"runtime/overload_off[{n}]", armed_s / n * 1e6,
         f"n={n};overhead={overhead:+.3f}")


# ------------------------------------------------------------------- driver
def run(emit, n: int | None = None):
    run_decision(emit, n=n)
    run_serve(emit, n=n)
    run_twin_exec(emit)
    run_fleet(emit)
    run_live_async(emit)
    if not common.REDUCED and n is None:
        run_million(emit)
        run_streaming(emit)
        run_sharded(emit)
        run_trace_planner(emit)
        run_jax_core(emit)
        run_residency(emit)
        run_chaos(emit)
        run_overload(emit)


def run_smoke(emit):
    """Seconds-long fleet perf smoke for CI: small sizes, relaxed bars
    (shared CI runners throttle unpredictably; the 10x/5x acceptance bars are
    judged at full size on the saturated case). The mixed cases only have to
    not be slowdowns — their value in CI is the bit-parity check. The live
    async-overlap floor is likewise relaxed to 1.3x in smoke (the ≥2x
    acceptance bar assumes ≥2 unthrottled cores and the full task count)."""
    run_decision(emit, n=8_000, min_speedup=4.0, mixed_min_speedup=1.0)
    run_serve(emit, n=8_000, min_speedup=3.0)
    run_twin_exec(emit, n=20_000, min_speedup=3.0, mixed_min_speedup=1.0)
    run_fleet(emit, n=1_200)
    run_live_async(emit, n=60, min_speedup=1.3)
    # streaming-scale smoke: small n, tracemalloc ceiling, relaxed rate floor
    # (shared CI runners throttle; the 10M scenario + >=1x floor run full)
    run_streaming(emit, n=200_000, n_oneshot=200_000, chunk=32_768,
                  min_rel_rate=0.7, smoke=True)
    # sharded smoke: tiny shards are overhead-dominated even on a 4-core
    # runner, so the floor is sanity-only — the cross-mode per-record parity
    # checks inside run_sharded are the smoke's real gate (the 2x acceptance
    # floor is judged at full size on >=4 unthrottled cores)
    run_sharded(emit, n_per_app=60_000, chunk=16_384, min_speedup=0.5)
    # trace replay + planner smoke: same 8-candidate search on a 50k-task
    # trace; only the replay-rate floor is relaxed (throttled runners), the
    # parity and cheapest-meets-SLO assertions hold at full strength
    run_trace_planner(emit, n=50_000, chunk=16_384, max_rel=1.4, smoke=True)
    # jax-core smoke: small-N bit-parity (interpret) + decision-equality
    # (compiled) + the no-retrace compile-cache gate; the >=2x speedup floor
    # is judged at full size on an accelerator only
    run_jax_core(emit, n=3_000, chunk=1_024, smoke=True)
    # residency smoke: the sync-count + decision-parity gates (resident vs
    # per-chunk, plus the 1-sync-per-fallback-exit budget) hold at full
    # strength; only the resident-vs-per-chunk rate floor is deferred to
    # full size on an accelerator
    run_residency(emit, n=3_000, chunk=1_024, smoke=True)
    # chaos smoke: the empty-FaultSpec bit-parity gate holds at full
    # strength; only the 3% overhead bar is relaxed (throttled runners —
    # the floor is judged at full size), plus the 1-of-3-devices-down
    # degradation scenario with its top-tier SLO assertion
    run_chaos(emit, n=8_000, max_overhead=0.25, smoke=True)
    # overload smoke: the prewarm cold-start cut, the reclamation SLO gate,
    # and the armed-idle bit-parity all hold at full strength (their
    # scenarios are fixed-size); only the 3% policies-off overhead bar is
    # relaxed (throttled runners — the floor is judged at full size)
    run_overload(emit, n=8_000, max_overhead=0.25, smoke=True)


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--n", type=int, default=None)
    args = p.parse_args()
    from benchmarks.common import CsvSink

    sink = CsvSink()
    run(sink, n=args.n)
    print(sink.dump())


if __name__ == "__main__":
    main()
