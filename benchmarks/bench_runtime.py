"""Runtime throughput benchmarks: batched decisions, vectorized twin
execution, and the edge-fleet scenario.

Three sections (run all via ``python benchmarks/run.py --only runtime``, or
this file directly; ``--smoke`` on run.py exercises the fleet sections in
seconds for CI):

1. **decision** — batched ``place_many`` vs the per-task ``place()`` loop on
   one FD workload; decisions must be identical, speedup ≥ 5x (ISSUE-1 bar;
   in practice >50x).
2. **twin-exec** — vectorized ``TwinBackend.execute_many`` vs the sequential
   ``execute`` loop on a 100k-task saturated-fleet workload (3 edge devices,
   bursty arrivals, edge-first budget). Outcomes must be bit-identical —
   ``execute_many`` consumes the same RNG streams — and throughput ≥ 10x.
   A mixed edge/cloud split is also reported (the cloud container-pool walk
   is inherently sequential, so its ratio is lower).
3. **fleet** — skewed (bursty) arrivals on a heterogeneous 3-device fleet:
   least-predicted-wait balancing must beat round-robin, and the fleet must
   beat the single-edge configuration on mean end-to-end latency. Per-device
   utilization/queue-wait summaries show the balance.

    PYTHONPATH=src:. python benchmarks/bench_runtime.py [--n 10000]
"""

from __future__ import annotations

import argparse
import time

from repro.core.decision import (
    DecisionEngine,
    LeastPredictedWaitBalancer,
    MinLatencyPolicy,
    PredictedEdgeQueue,
    RoundRobinBalancer,
)
from repro.core.fit import build_fleet_predictor, build_predictor, fit_app
from repro.core.runtime import PlacementRuntime, TwinBackend
from repro.core.workload import BurstyWorkload
from benchmarks import common
from benchmarks.common import banner

CONFIGS = (1280, 1536, 1792, 2048)
C_MAX, ALPHA = 2.97e-5, 0.02

# the fleet scenario: two full-speed devices + one slower straggler
FLEET_SPEEDS = {"edge0": 1.0, "edge1": 1.0, "edge2": 0.6}
FLEET_NAMES = tuple(FLEET_SPEEDS)
FLEET_C_MAX = 2e-6  # edge-first budget: bursts must be absorbed by the fleet


def _bursty(twin, n: int, rate_per_s: float = 4.0, seed: int = 7):
    return BurstyWorkload(rate_per_s=rate_per_s, size_sampler=twin.sample_input,
                          burst_multiplier=6.0, mean_quiet_s=15.0,
                          mean_burst_s=6.0, seed=seed).generate(n)


# ------------------------------------------------------------- 1. decisions
def _fresh_engine(models):
    pred = build_predictor(models, configs=CONFIGS)
    return DecisionEngine(predictor=pred, policy=MinLatencyPolicy(C_MAX, ALPHA))


def run_decision(emit, n: int | None = None):
    if n is None:
        n = 2_000 if common.REDUCED else 10_000
    banner(f"bench_runtime/decision — place_many vs per-task place ({n} tasks)")
    twin, models = fit_app("FD", seed=0, n_inputs=200, configs=CONFIGS)
    tasks = twin.workload(n, seed=3)

    # --- per-task decision loop (the pre-redesign serve path) --------------
    eng_loop = _fresh_engine(models)
    queue = PredictedEdgeQueue()
    t0 = time.perf_counter()
    for t in tasks:
        d = eng_loop.place(t, t.arrival_ms,
                           edge_queue_wait_ms=queue.wait_ms(t.arrival_ms))
        if d.target == eng_loop.edge_name:
            queue.push(t.arrival_ms, d.prediction.comp_ms)
    loop_s = time.perf_counter() - t0

    # --- batched decision loop --------------------------------------------
    eng_batch = _fresh_engine(models)
    t0 = time.perf_counter()
    decisions = eng_batch.place_many(tasks)
    batch_s = time.perf_counter() - t0

    mismatches = sum(a.target != b.target
                     for a, b in zip(eng_loop.decisions, decisions))
    speedup = loop_s / max(batch_s, 1e-12)
    print(f"{'path':<22} {'wall s':>10} {'tasks/s':>12}")
    print(f"{'per-task place()':<22} {loop_s:>10.3f} {n / loop_s:>12.0f}")
    print(f"{'place_many()':<22} {batch_s:>10.3f} {n / batch_s:>12.0f}")
    print(f"speedup: {speedup:.1f}x   decision mismatches: {mismatches}/{n}")
    assert mismatches == 0, "batched decisions diverged from per-task loop"
    assert speedup >= 5.0, f"expected >=5x, got {speedup:.1f}x"

    emit("runtime/place_per_task", loop_s / n * 1e6, f"n={n}")
    emit("runtime/place_many", batch_s / n * 1e6,
         f"n={n};speedup={speedup:.1f}x")


# ----------------------------------------------------- 2. twin execution
def _twin_exec_case(emit, twin, tasks, targets, label: str, min_speedup: float,
                    reps: int = 3):
    """Best-of-``reps`` wall time per path (standard microbenchmark
    de-noising — each rep uses a fresh backend, so every run does identical
    work from identical state)."""
    n = len(tasks)
    seq_s = vec_s = float("inf")
    outs_seq = batch = None
    for _ in range(reps):
        b_seq = TwinBackend(twin, seed=11, edge_names=FLEET_NAMES,
                            edge_speed=FLEET_SPEEDS)
        t0 = time.perf_counter()
        outs_seq = [b_seq.execute(t, tg, t.arrival_ms)
                    for t, tg in zip(tasks, targets)]
        seq_s = min(seq_s, time.perf_counter() - t0)

        b_vec = TwinBackend(twin, seed=11, edge_names=FLEET_NAMES,
                            edge_speed=FLEET_SPEEDS)
        t0 = time.perf_counter()
        batch = b_vec.execute_many(tasks, targets)
        vec_s = min(vec_s, time.perf_counter() - t0)

    identical = outs_seq == batch.outcomes()
    speedup = seq_s / max(vec_s, 1e-12)
    edge_pct = 100.0 * sum(1 for tg in targets if tg in FLEET_SPEEDS) / n
    print(f"{label:<18} edge {edge_pct:5.1f}%  "
          f"seq {n / seq_s:>9.0f} t/s  vec {n / vec_s:>10.0f} t/s  "
          f"speedup {speedup:5.1f}x  identical={identical}")
    assert identical, f"{label}: vectorized outcomes diverged from execute()"
    assert speedup >= min_speedup, \
        f"{label}: expected >={min_speedup}x, got {speedup:.1f}x"
    emit(f"runtime/execute_seq[{label}]", seq_s / n * 1e6, f"n={n}")
    emit(f"runtime/execute_many[{label}]", vec_s / n * 1e6,
         f"n={n};speedup={speedup:.1f}x")
    return speedup


def run_twin_exec(emit, n: int | None = None, min_speedup: float = 10.0,
                  mixed_min_speedup: float = 3.0):
    if n is None:
        n = 20_000 if common.REDUCED else 100_000
    banner(f"bench_runtime/twin-exec — execute_many vs execute loop ({n} tasks)")
    twin, models = fit_app("STT", seed=0, n_inputs=120, configs=CONFIGS)
    tasks = _bursty(twin, n, rate_per_s=3.0, seed=3)

    def targets_for(c_max):
        eng = DecisionEngine(
            predictor=build_fleet_predictor(models, FLEET_SPEEDS, configs=CONFIGS),
            policy=MinLatencyPolicy(c_max=c_max, alpha=0.01))
        return [d.target for d in eng.place_many(tasks)]

    # saturated fleet: the budget keeps the whole burst load on the devices —
    # the regime the vectorized sampler exists for (and the acceptance bar)
    _twin_exec_case(emit, twin, tasks, targets_for(0.0),
                    "fleet-saturated", min_speedup)
    # mixed split: the cloud container-pool walk is sequential bookkeeping,
    # so the ratio is structurally lower — reported with a soft sanity bar
    _twin_exec_case(emit, twin, tasks, targets_for(2e-5), "mixed-cloud",
                    mixed_min_speedup)


# ------------------------------------------------------------- 3. the fleet
def _fleet_runtime(twin, models, balancer=None, devices=None):
    devices = devices if devices is not None else dict(FLEET_SPEEDS)
    pred = build_fleet_predictor(models, devices, configs=CONFIGS)
    kwargs = {"balancer": balancer} if balancer is not None else {}
    eng = DecisionEngine(predictor=pred,
                         policy=MinLatencyPolicy(c_max=FLEET_C_MAX, alpha=ALPHA),
                         **kwargs)
    backend = TwinBackend(twin, seed=11, edge_names=tuple(devices),
                          edge_speed=devices)
    return PlacementRuntime(eng, backend)


def _single_runtime(twin, models):
    pred = build_predictor(models, configs=CONFIGS)
    eng = DecisionEngine(predictor=pred,
                         policy=MinLatencyPolicy(c_max=FLEET_C_MAX, alpha=ALPHA))
    return PlacementRuntime(eng, TwinBackend(twin, seed=11))


def run_fleet(emit, n: int | None = None):
    if n is None:
        n = 1_500 if common.REDUCED else 4_000
    banner(f"bench_runtime/fleet — 3-device fleet vs single edge, "
           f"skewed arrivals ({n} tasks)")
    twin, models = fit_app("IR", seed=0, n_inputs=150, configs=CONFIGS)
    tasks = _bursty(twin, n)

    lpw = _fleet_runtime(twin, models, LeastPredictedWaitBalancer()).serve(tasks)
    rr = _fleet_runtime(twin, models, RoundRobinBalancer()).serve(tasks)
    single = _single_runtime(twin, models).serve(tasks)

    rows = [("fleet-3 least-wait", lpw), ("fleet-3 round-robin", rr),
            ("single edge", single)]
    print(f"{'configuration':<22} {'mean ms':>9} {'p99 ms':>10} {'edge#':>6}")
    for name, res in rows:
        print(f"{name:<22} {res.avg_actual_latency_ms:>9.0f} "
              f"{res.p99_actual_latency_ms:>10.0f} {res.n_edge:>6d}")
    print("\nleast-wait fleet balance:")
    print(lpw.device_table())

    assert lpw.avg_actual_latency_ms < single.avg_actual_latency_ms, \
        "fleet must beat the single-edge configuration on mean latency"
    assert lpw.avg_actual_latency_ms < rr.avg_actual_latency_ms, \
        "least-predicted-wait must beat round-robin on skewed arrivals"
    emit("runtime/fleet_lpw_mean_us", lpw.avg_actual_latency_ms * 1e3, f"n={n}")
    emit("runtime/fleet_rr_mean_us", rr.avg_actual_latency_ms * 1e3, f"n={n}")
    emit("runtime/single_edge_mean_us", single.avg_actual_latency_ms * 1e3,
         f"n={n}")


# ------------------------------------------------------------------- driver
def run(emit, n: int | None = None):
    run_decision(emit, n=n)
    run_twin_exec(emit)
    run_fleet(emit)


def run_smoke(emit):
    """Seconds-long fleet perf smoke for CI: small sizes, relaxed exec bars
    (shared CI runners throttle unpredictably; the 10x acceptance bar is
    judged at full size on the saturated case). The mixed case only has to
    not be a slowdown — its value in CI is the bit-parity check."""
    run_twin_exec(emit, n=20_000, min_speedup=3.0, mixed_min_speedup=1.0)
    run_fleet(emit, n=1_200)


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--n", type=int, default=None)
    args = p.parse_args()
    from benchmarks.common import CsvSink

    sink = CsvSink()
    run(sink, n=args.n)
    print(sink.dump())


if __name__ == "__main__":
    main()
