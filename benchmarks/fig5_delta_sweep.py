"""Fig. 5: total execution cost and #edge executions vs. deadline δ.

Paper claims validated qualitatively per app (best Table-III config set):
- predicted total cost closely tracks actual cost across δ;
- IR: edge executions roughly independent of δ (edge is fast for IR);
- STT: edge executions increase with δ (slow arrivals leave the edge free).
"""

from __future__ import annotations

import numpy as np

from repro.core.decision import MinCostPolicy
from benchmarks.common import banner, simulate

BEST = {
    "IR": ((640, 1024, 1152), [1800, 2200, 2700, 3200, 3700]),
    "FD": ((1280, 1408, 1664), [3500, 4000, 4500, 5000, 5500]),
    "STT": ((768, 1152, 1280, 1664), [4500, 5000, 5500, 6000, 6500]),
}


def run(emit):
    banner("Fig. 5 — total cost (pred vs actual) and edge executions vs δ")
    for app, (configs, deltas) in BEST.items():
        print(f"\n[{app}] configs={configs}")
        print(f"{'δ (s)':>6} {'actual $':>12} {'pred $':>12} {'err%':>6} {'edge#':>6}")
        errs, edge_counts = [], []
        for d in deltas:
            res, us = simulate(app, lambda dd=d: MinCostPolicy(dd), configs,
                               seed=int(d) % 97)
            err = res.cost_error_pct
            errs.append(err)
            edge_counts.append(res.n_edge)
            print(f"{d/1e3:>6.1f} {res.total_actual_cost:>12.8f} "
                  f"{res.total_predicted_cost:>12.8f} {err:>5.1f}% "
                  f"{res.n_edge:>6d}")
            emit(f"fig5/{app}/delta={d}", us,
                 f"cost={res.total_actual_cost:.8f};edge={res.n_edge}")
        print(f"  mean |cost err| across δ: {np.mean(errs):.2f}%")
        if app == "STT":
            assert edge_counts[-1] >= edge_counts[0], \
                "STT: edge executions should grow with δ"


if __name__ == "__main__":
    from benchmarks.common import CsvSink

    sink = CsvSink()
    run(sink)
    print(sink.dump())
