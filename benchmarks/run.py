"""Benchmark harness: one module per paper table/figure + roofline.

Prints each table (human-readable) and finishes with the canonical
``name,us_per_call,derived`` CSV. ``--reduced`` trims data-collection sizes
for quick runs; ``--only t3,t5`` selects modules.
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--reduced", action="store_true",
                   help="smaller measurement sets (quick run)")
    p.add_argument("--only", default="",
                   help="comma list: t1,t2,t3,t4,t5,fig5,fig6,beyond,runtime,roofline")
    p.add_argument("--skip-live", action="store_true",
                   help="skip the real-compile live prototype (t5)")
    p.add_argument("--smoke", action="store_true",
                   help="seconds-long fleet perf smoke (CI): vectorized twin "
                        "execution + fleet-vs-single-edge scenario only")
    args = p.parse_args()

    from benchmarks import common
    if args.reduced or args.smoke:
        common.REDUCED = True

    if args.smoke:
        from benchmarks import bench_runtime

        sink = common.CsvSink()
        t0 = time.time()
        bench_runtime.run_smoke(sink)
        print(f"\n# smoke wall: {time.time() - t0:.1f}s")
        print(sink.dump())
        return 0

    from benchmarks import (
        bench_runtime,
        beyond_paper,
        fig5_delta_sweep,
        fig6_alpha_sweep,
        roofline,
        table1_components,
        table2_mape,
        table3_costmin,
        table4_latmin,
        table5_live,
    )

    # t5 (the live prototype) runs FIRST: its latencies are wall-clock
    # measurements and the cleanest process state gives the fairest numbers
    # (running it after the numpy-heavy fits adds ~2-3x noise to sub-100ms
    # measurements — both orderings are honest, this one is reproducible).
    modules = {
        "t5": table5_live.run,
        "t1": table1_components.run,
        "t2": table2_mape.run,
        "t3": table3_costmin.run,
        "t4": table4_latmin.run,
        "fig5": fig5_delta_sweep.run,
        "fig6": fig6_alpha_sweep.run,
        "beyond": beyond_paper.run,
        "runtime": bench_runtime.run,
        "roofline": roofline.run,
    }
    selected = [s.strip() for s in args.only.split(",") if s.strip()] or list(modules)
    if args.skip_live and "t5" in selected:
        selected.remove("t5")

    sink = common.CsvSink()
    failures = []
    t0 = time.time()
    for name in selected:
        try:
            if name == "roofline":
                modules[name](sink)
                modules[name](sink, mesh="multipod")
                path = roofline.write_markdown()
                print(f"(roofline markdown → {path})")
            else:
                modules[name](sink)
        except Exception:
            failures.append(name)
            print(f"\nBENCHMARK {name} FAILED:\n{traceback.format_exc()}",
                  file=sys.stderr)

    print(f"\n# total wall: {time.time()-t0:.1f}s")
    print(sink.dump())
    if failures:
        print(f"\nFAILED benchmarks: {failures}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
