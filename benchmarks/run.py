"""Benchmark harness: one module per paper table/figure + roofline.

Prints each table (human-readable) and finishes with the canonical
``name,us_per_call,derived`` CSV. ``--reduced`` trims data-collection sizes
for quick runs; ``--only t3,t5`` selects modules; ``--json <path>`` also
writes the rows as machine-readable JSON (``BENCH_runtime.json`` in CI — the
perf trajectory consumed by dashboards and regression tooling).
"""

from __future__ import annotations

import argparse
import json
import re
import sys
import time
import traceback


def write_json(sink, path: str, smoke: bool, reduced: bool) -> None:
    """Dump the sink's rows as ``{scenario: {us_per_call, speedup?, derived}}``.

    ``speedup`` is parsed out of the derived field (``speedup=12.3x``) when a
    benchmark reported one, so perf floors are first-class numbers.
    """
    rows = {}
    for name, us, derived in sink.rows:
        row = {"us_per_call": round(us, 3), "derived": derived}
        m = re.search(r"speedup=([0-9.]+)x", derived)
        if m:
            row["speedup"] = float(m.group(1))
        rows[name] = row
    payload = {
        "schema": "bench_runtime/v1",
        "smoke": smoke,
        "reduced": reduced,
        "rows": rows,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"(json → {path}: {len(rows)} rows)")


def main() -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--reduced", action="store_true",
                   help="smaller measurement sets (quick run)")
    p.add_argument("--only", default="",
                   help="comma list: t1,t2,t3,t4,t5,fig5,fig6,beyond,runtime,roofline")
    p.add_argument("--skip-live", action="store_true",
                   help="skip the real-compile live prototype (t5)")
    p.add_argument("--smoke", action="store_true",
                   help="seconds-long fleet perf smoke (CI): columnar "
                        "decisions, array-native serve, vectorized twin "
                        "execution + fleet-vs-single-edge scenario")
    p.add_argument("--json", default="",
                   help="also write results as JSON to this path "
                        "(BENCH_runtime.json in CI)")
    args = p.parse_args()

    from benchmarks import common
    if args.reduced or args.smoke:
        common.REDUCED = True

    if args.smoke:
        from benchmarks import bench_runtime

        sink = common.CsvSink()
        t0 = time.time()
        bench_runtime.run_smoke(sink)
        print(f"\n# smoke wall: {time.time() - t0:.1f}s")
        print(sink.dump())
        if args.json:
            write_json(sink, args.json, smoke=True, reduced=common.REDUCED)
        return 0

    from benchmarks import (
        bench_runtime,
        beyond_paper,
        fig5_delta_sweep,
        fig6_alpha_sweep,
        roofline,
        table1_components,
        table2_mape,
        table3_costmin,
        table4_latmin,
        table5_live,
    )

    # t5 (the live prototype) runs FIRST: its latencies are wall-clock
    # measurements and the cleanest process state gives the fairest numbers
    # (running it after the numpy-heavy fits adds ~2-3x noise to sub-100ms
    # measurements — both orderings are honest, this one is reproducible).
    modules = {
        "t5": table5_live.run,
        "t1": table1_components.run,
        "t2": table2_mape.run,
        "t3": table3_costmin.run,
        "t4": table4_latmin.run,
        "fig5": fig5_delta_sweep.run,
        "fig6": fig6_alpha_sweep.run,
        "beyond": beyond_paper.run,
        "runtime": bench_runtime.run,
        "roofline": roofline.run,
    }
    selected = [s.strip() for s in args.only.split(",") if s.strip()] or list(modules)
    if args.skip_live and "t5" in selected:
        selected.remove("t5")

    sink = common.CsvSink()
    failures = []
    t0 = time.time()
    for name in selected:
        try:
            if name == "roofline":
                modules[name](sink)
                modules[name](sink, mesh="multipod")
                path = roofline.write_markdown()
                print(f"(roofline markdown → {path})")
            else:
                modules[name](sink)
        except Exception:
            failures.append(name)
            print(f"\nBENCHMARK {name} FAILED:\n{traceback.format_exc()}",
                  file=sys.stderr)

    print(f"\n# total wall: {time.time()-t0:.1f}s")
    print(sink.dump())
    if args.json:
        write_json(sink, args.json, smoke=False, reduced=common.REDUCED)
    if failures:
        print(f"\nFAILED benchmarks: {failures}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
