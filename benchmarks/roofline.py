"""Roofline analysis from the dry-run's compiled artifacts (§Roofline).

Hardware constants (TPU v5e): 197 TFLOP/s bf16/chip, 819 GB/s HBM/chip,
~50 GB/s/link ICI. For each (arch × shape × mesh) cell recorded by
``repro.launch.dryrun`` this derives:

    compute term    = HLO_FLOPs(dev)        / peak_FLOPs
    memory term     = HLO_bytes(dev)        / HBM_bw
    collective term = collective_bytes(dev) / link_bw

(the dry-run HLO is the post-GSPMD per-device program, so all numbers are
per-device already), plus MODEL_FLOPS = 6·N_active·tokens (train) or
2·N_active·tokens (inference), the useful-compute ratio, the dominant term,
and the roofline fraction = useful-compute time / dominant term.
"""

from __future__ import annotations

import glob
import json
import os
import time

from benchmarks.common import banner

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # B/s / chip
LINK_BW = 50e9            # B/s / link

TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,       # one token per sequence per step
    "long_500k": 1,
}

ADVICE = {
    "compute": "raise MFU: larger per-step tiles, fuse elementwise into dots, "
               "cut remat recompute",
    "memory": "cut HBM traffic: better fusion/layout, bf16 activations, "
              "avoid full-logit materialization",
    "collective": "cut link bytes: reshard (reduce-scatter instead of "
                  "all-reduce), overlap collectives with compute, shard "
                  "activations over fewer TP ops, gradient compression "
                  "across pods",
}


def analyze_cell(d: dict) -> dict:
    hlo = d["hlo"]
    kind = d["kind"]
    devices = d["devices"]
    n_active = d.get("active_param_count") or d["param_count"]
    tokens = TOKENS[d["shape"]]
    mult = 6.0 if kind == "train" else 2.0
    model_flops_dev = mult * n_active * tokens / devices

    t_c = hlo["flops"] / PEAK_FLOPS
    t_m = hlo["hbm_bytes"] / HBM_BW
    t_l = hlo["collective_link_bytes"] / LINK_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_l}
    dom = max(terms, key=terms.get)
    bound = terms[dom]
    useful_t = model_flops_dev / PEAK_FLOPS
    return {
        "arch": d["arch"], "shape": d["shape"], "mesh": d["mesh"],
        "kind": kind,
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_l,
        "dominant": dom,
        "model_flops_dev": model_flops_dev,
        "useful_ratio": model_flops_dev / max(hlo["flops"], 1e-9),
        "roofline_frac": useful_t / max(bound, 1e-12),
        "peak_gib": d.get("memory", {}).get("peak_bytes_estimate", 0) / 2**30,
        "advice": ADVICE[dom],
    }


def load_cells(dryrun_dir: str = "experiments/dryrun", mesh: str = "pod",
               tag: str = "") -> list[dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, f"*_{mesh}{tag}.json"))):
        base = os.path.basename(f)
        if not tag and not base.endswith(f"_{mesh}.json"):
            continue  # don't match tagged variants when untagged requested
        with open(f) as fh:
            out.append(analyze_cell(json.load(fh)))
    return out


def run(emit, mesh: str = "pod"):
    banner(f"Roofline — per (arch × shape), {mesh} mesh "
           "(terms in ms/step/device)")
    cells = load_cells(mesh=mesh)
    if not cells:
        print("no dry-run artifacts found — run "
              "`python -m repro.launch.dryrun --all` first")
        return
    print(f"{'arch':>26} {'shape':<12} {'comp ms':>9} {'mem ms':>8} "
          f"{'coll ms':>8} {'bound':<10} {'useful':>7} {'roofline':>9}")
    t0 = time.perf_counter()
    for c in cells:
        print(f"{c['arch']:>26} {c['shape']:<12} "
              f"{c['compute_s']*1e3:>9.2f} {c['memory_s']*1e3:>8.2f} "
              f"{c['collective_s']*1e3:>8.2f} {c['dominant']:<10} "
              f"{c['useful_ratio']:>6.1%} {c['roofline_frac']:>8.1%}")
        emit(f"roofline/{c['arch']}/{c['shape']}/{mesh}",
             (time.perf_counter() - t0) * 1e6 / max(len(cells), 1),
             f"dominant={c['dominant']};roofline={c['roofline_frac']:.3f}"
             f";useful={c['useful_ratio']:.3f}")
    # summary: dominant-term histogram
    hist: dict[str, int] = {}
    for c in cells:
        hist[c["dominant"]] = hist.get(c["dominant"], 0) + 1
    print(f"\ndominant-term histogram: {hist}")
    worst = sorted(cells, key=lambda c: c["roofline_frac"])[:3]
    print("worst roofline fractions (hillclimb candidates):")
    for c in worst:
        print(f"  {c['arch']} {c['shape']}: {c['roofline_frac']:.1%} "
              f"({c['dominant']}-bound) → {c['advice']}")


def write_markdown(path: str = "experiments/roofline.md"):
    """EXPERIMENTS.md §Roofline source table (both meshes)."""
    lines = ["| arch | shape | mesh | compute ms | memory ms | collective ms "
             "| dominant | useful | roofline | peak GiB |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for mesh in ("pod", "multipod"):
        for c in load_cells(mesh=mesh):
            lines.append(
                f"| {c['arch']} | {c['shape']} | {c['mesh']} "
                f"| {c['compute_s']*1e3:.2f} | {c['memory_s']*1e3:.2f} "
                f"| {c['collective_s']*1e3:.2f} | {c['dominant']} "
                f"| {c['useful_ratio']:.1%} | {c['roofline_frac']:.1%} "
                f"| {c['peak_gib']:.2f} |")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
    return path


if __name__ == "__main__":
    from benchmarks.common import CsvSink

    sink = CsvSink()
    run(sink)
    run(sink, mesh="multipod")
    print("\nwrote", write_markdown())
    print(sink.dump())
