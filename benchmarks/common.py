"""Shared benchmark infrastructure: fitted-model cache, timing, CSV rows.

Every benchmark module exposes ``run(emit)`` where ``emit(name, us_per_call,
derived)`` appends one canonical CSV row; modules also print their
human-readable table (the EXPERIMENTS.md source).
"""

from __future__ import annotations

import time
from functools import lru_cache

import numpy as np

from repro.core.decision import DecisionEngine
from repro.core.fit import build_predictor, fit_app
from repro.core.runtime import PlacementRuntime, TwinBackend

# Paper Sec. IV-C data sizes (1400 imgs / 3400 clips, 19 configs) are used in
# full by default; REDUCED=True trims for quick runs (CI) without changing
# any methodology.
REDUCED = False


def n_inputs_for(app: str) -> int | None:
    if not REDUCED:
        return None  # paper-faithful default (1400 / 3400)
    return 250


def n_tasks() -> int:
    return 600 if not REDUCED else 200  # paper Sec. VI-A: 600 fresh inputs


@lru_cache(maxsize=None)
def fitted(app: str, seed: int = 0):
    """(twin, FittedModels) for one paper application, cached per process."""
    return fit_app(app, seed=seed, n_inputs=n_inputs_for(app))


def simulate(app: str, policy_factory, configs, seed: int = 5,
             quantile: float | None = None, n: int | None = None):
    """One simulation run; returns (SimulationResult, decision_us)."""
    twin, models = fitted(app)
    tasks = twin.workload(n or n_tasks(), seed=seed)
    pred = build_predictor(models, configs=tuple(configs), quantile=quantile)
    eng = DecisionEngine(predictor=pred, policy=policy_factory())
    runtime = PlacementRuntime(engine=eng, backend=TwinBackend(twin, seed=seed + 100))
    t0 = time.perf_counter()
    res = runtime.serve(tasks)
    wall = time.perf_counter() - t0
    return res, wall / max(len(tasks), 1) * 1e6


class CsvSink:
    def __init__(self):
        self.rows: list[tuple[str, float, str]] = []

    def __call__(self, name: str, us_per_call: float, derived):
        self.rows.append((name, float(us_per_call), str(derived)))

    def dump(self) -> str:
        out = ["name,us_per_call,derived"]
        out += [f"{n},{u:.2f},{d}" for n, u, d in self.rows]
        return "\n".join(out)


def fmt_pct(x: float) -> str:
    return f"{x:.2f}%"


def banner(title: str):
    print("\n" + "=" * 78)
    print(title)
    print("=" * 78)
