"""Table IV: minimize latency subject to a per-task budget (Alg. 1).

Paper setup: C_max and α per app chosen so some inputs must use λ_edge;
600 fresh inputs. Reported per set: average actual time/task, |latency
prediction error| %, % cost constraints violated, % budget used.
"""

from __future__ import annotations

from repro.core.decision import MinLatencyPolicy
from benchmarks.common import banner, fmt_pct, simulate

# Paper Table IV parameters + config sets (λ_edge always included).
SETS = {
    "IR": (5.33442e-06, 0.02, [
        (1408, 1664, 2944),
        (1536, 1664, 2048, 2944),
        (1280, 1536, 1664, 2944),
        (1280, 1408, 1536, 2944),
    ]),
    "FD": (2.96997e-05, 0.02, [
        (1536, 1664, 2048),
        (1664, 1920, 2048),
        (1280, 1664, 2048),
        (1536, 1664, 1920),
    ]),
    "STT": (3.0747e-05, 0.03, [
        (1152, 1280, 1664),
        (1664,),
        (1024, 1280, 1664),
        (1024, 1152, 1280, 1664),
    ]),
}


def run(emit):
    banner("Table IV — min latency s.t. cost ≤ C_max + α·surplus (Alg. 1)")
    for app, (c_max, alpha, sets) in SETS.items():
        print(f"\n[{app}]  C_max = ${c_max:.6g}, α = {alpha}")
        print(f"{'config set':<26} {'avg time/task s':>16} {'lat err':>8} "
              f"{'% viol':>7} {'% budget':>9}")
        best = None
        for configs in sets:
            res, us = simulate(
                app, lambda c=c_max, a=alpha: MinLatencyPolicy(c, a), configs)
            label = ",".join(map(str, configs))
            print(f"{label:<26} {res.avg_actual_latency_ms/1e3:>16.4f} "
                  f"{fmt_pct(res.latency_error_pct):>8} "
                  f"{fmt_pct(res.pct_cost_violated):>7} "
                  f"{res.pct_budget_used:>8.1f}%")
            emit(f"table4/{app}/{label}", us,
                 f"avg_s={res.avg_actual_latency_ms/1e3:.4f}"
                 f";lat_err={res.latency_error_pct:.2f}%"
                 f";budget={res.pct_budget_used:.1f}%")
            if best is None or res.avg_actual_latency_ms < best[1]:
                best = (label, res.avg_actual_latency_ms)
        print(f"  -> best set: {best[0]} (avg {best[1]/1e3:.3f} s/task)")


if __name__ == "__main__":
    from benchmarks.common import CsvSink

    sink = CsvSink()
    run(sink)
    print(sink.dump())
