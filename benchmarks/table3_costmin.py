"""Table III: minimize cost subject to a per-task deadline δ.

Paper setup (Sec. VI-A1): per app, config sets selected on training data, 600
fresh inputs, Poisson arrivals (4/s IR+FD, 0.1/s STT). Reported per set:
total actual cost, |cost prediction error| %, % deadlines violated, average
violation (ms). Paper deadlines: IR δ=2.7 s, FD δ=4.5 s, STT δ=5.5 s.
"""

from __future__ import annotations

from repro.core.decision import MinCostPolicy
from benchmarks.common import banner, fmt_pct, simulate

# Paper Table III config sets (λ_edge always included).
SETS = {
    "IR": (2700.0, [
        (640, 1024, 1152),
        (640, 1024, 1408),
        (640, 896, 1152, 1280),
        (640, 768, 1152),
    ]),
    "FD": (4500.0, [
        (1280, 1408, 1664),
        (1152, 1408, 1664),
        (1152, 1536, 1792),
        (1280, 1408, 1536, 1792),
    ]),
    "STT": (5500.0, [
        (768, 1152, 1280, 1664),
        (640, 768, 1280, 1664, 1792),
        (640, 768, 896, 1280, 1664),
        (640, 896, 1152, 1664),
    ]),
}


def run(emit):
    banner("Table III — min cost s.t. deadline (600 inputs, Poisson arrivals)")
    for app, (deadline, sets) in SETS.items():
        print(f"\n[{app}]  δ = {deadline/1e3:.1f} s")
        print(f"{'config set':<28} {'total cost $':>13} {'cost err':>9} "
              f"{'% viol':>7} {'avg viol ms':>12}")
        best = None
        for configs in sets:
            res, us = simulate(app, lambda d=deadline: MinCostPolicy(d), configs)
            label = ",".join(map(str, configs))
            print(f"{label:<28} {res.total_actual_cost:>13.8f} "
                  f"{fmt_pct(res.cost_error_pct):>9} "
                  f"{fmt_pct(res.pct_deadline_violated):>7} "
                  f"{res.avg_violation_ms:>12.2f}")
            emit(f"table3/{app}/{label}", us,
                 f"cost={res.total_actual_cost:.8f}"
                 f";cost_err={res.cost_error_pct:.2f}%"
                 f";viol={res.pct_deadline_violated:.2f}%")
            if best is None or res.total_actual_cost < best[1]:
                best = (label, res.total_actual_cost, res.cost_error_pct)
        print(f"  -> best set: {best[0]} "
              f"(cost ${best[1]:.8f}, pred err {best[2]:.2f}%)")


if __name__ == "__main__":
    from benchmarks.common import CsvSink

    sink = CsvSink()
    run(sink)
    print(sink.dump())
