"""End-to-end serving driver: dynamic task placement over REAL model executions.

This is the live-prototype path (paper Sec. VI-B) on the TPU-fleet adaptation:
slice configs λ_m = {2, 4, 8}-chip executors serving a (reduced) llama3.2-1b;
cold start = a real XLA compile; a Poisson stream of LLM requests flows
through the Decision Engine; every latency is wall-clock measured.

    PYTHONPATH=src python examples/serve_placement.py
"""

from repro.configs import smoke_config
from repro.core.decision import MinLatencyPolicy
from repro.serving.executors import SliceSpec
from repro.serving.placement import (
    calibrate_catalog,
    llm_workload,
    make_live_runtime,
)

MODEL = "llama3.2-1b"
CHIPS = (2, 4, 8)
N_REQUESTS = 80
RATE_PER_S = 50.0       # virtual arrival clock (~4× edge capacity)
MEAN_TOKENS = 4096.0
C_MAX = 2.0e-4          # $/request budget
ALPHA = 0.02

cfg = smoke_config(MODEL)
specs = [SliceSpec(f"slice{c}", c, tokens_per_step=4) for c in CHIPS]

print(f"calibrating {len(specs)} slice configs on reduced {MODEL} "
      "(real XLA compiles)...")
from repro.core.pricing import SlicePricing

cat = calibrate_catalog(cfg, specs, n_tasks=12, n_cold=1, seed=0,
                        pricing=SlicePricing(quantum_s=0.1),
                        mean_tokens=MEAN_TOKENS)
print(f"  cold start (compile+init): {cat.start_cold.mean:.0f} ms   "
      f"warm start: {cat.start_warm.mean:.2f} ms")

tasks = llm_workload(N_REQUESTS, rate_per_s=RATE_PER_S, seed=1,
                     mean_tokens=MEAN_TOKENS)
# The SAME PlacementRuntime serve loop as the simulator, over the live pool.
runtime = make_live_runtime(cat, MinLatencyPolicy(C_MAX, ALPHA),
                            t_idl_ms=10_000.0)
print(f"serving {N_REQUESTS} requests (Poisson {RATE_PER_S}/s) through the "
      "Decision Engine...")
res = runtime.serve(tasks)

hist = {}
for r in res.records:
    hist[r.target] = hist.get(r.target, 0) + 1

print(f"\navg end-to-end latency : {res.avg_actual_latency_ms:.1f} ms "
      f"(p95 {res.p95_actual_latency_ms:.1f} ms)")
print(f"latency prediction err : {res.latency_error_pct:.2f} %  "
      "(paper live prototype: 5.65 %)")
print(f"total cost             : ${res.total_actual_cost:.6f} "
      f"({res.pct_budget_used:.1f} % of budget)")
print(f"warm/cold mismatches   : {res.n_warm_cold_mismatches}/{res.n}")
print(f"placement histogram    : {dict(sorted(hist.items()))}")
