"""Train a ~100M-parameter llama-family model for a few hundred steps on CPU,
with the production loop: checkpointing, auto-resume, straggler watchdog.

A mid-run failure is injected to demonstrate checkpoint/restart fault
tolerance — the supervisor restarts from the last checkpoint and the loss
curve continues bit-exactly.

    PYTHONPATH=src python examples/train_100m.py [--steps 200]
"""

import argparse
import tempfile

import jax

from repro.configs import get_config
from repro.modeling.registry import build_model
from repro.training.data import make_pipeline
from repro.training.optimizer import OptimizerConfig
from repro.training.train_loop import (
    FailureInjector,
    LoopConfig,
    run_with_restarts,
)

p = argparse.ArgumentParser()
p.add_argument("--steps", type=int, default=200)
p.add_argument("--batch", type=int, default=8)
p.add_argument("--seq", type=int, default=256)
args = p.parse_args()

# ~100M params: llama3.2-1b narrowed (d_model 768, 12 layers, vocab 32k)
cfg = get_config("llama3.2-1b").with_updates(
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
    d_ff=2048, vocab=32000, dtype="float32", remat="none",
    q_chunk=128, loss_chunk=128, scan_layers=True)
model = build_model(cfg)
print(f"model: {model.param_count()/1e6:.1f}M params "
      f"({cfg.n_layers}L d={cfg.d_model}) on {len(jax.devices())} device(s)")

pipeline = make_pipeline(cfg, seq_len=args.seq, global_batch=args.batch, seed=0)

with tempfile.TemporaryDirectory() as ckpt_dir:
    loop = LoopConfig(steps=args.steps, log_every=max(args.steps // 10, 1),
                      ckpt_every=25, ckpt_dir=ckpt_dir, keep=2)
    opt = OptimizerConfig(peak_lr=3e-4, warmup_steps=20, decay_steps=args.steps)
    injector = FailureInjector(fail_at=args.steps // 2)
    print(f"training {args.steps} steps; a node failure is injected at step "
          f"{args.steps // 2} (expect restart + resume)...")
    res = run_with_restarts(model, pipeline, loop, opt,
                            key=jax.random.key(0), injector=injector,
                            log=print)
    print(f"\nfinal: step {res.final_step}, "
          f"loss {res.losses[0]:.3f} → {res.losses[-1]:.3f}, "
          f"restarts {res.restarts}, stragglers {res.straggler_steps}")
    assert res.losses[-1] < res.losses[0], "loss should decrease"
