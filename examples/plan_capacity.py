"""Trace-driven capacity planning: record traffic, replay what-ifs, pick the
cheapest configuration that meets the SLO.

The workflow an operator actually runs:

1. **Record** a day of traffic — here by capturing a served run of the STT
   smart-speaker workload into a ``Trace`` (in production the trace would
   come from the platform's request log) and round-tripping it through disk
   to show the format is bit-exact;
2. **Replay** it: a ``TraceWorkload`` streamed through ``serve_stream`` is
   bit-identical per record to serving the original in-memory workload;
3. **Plan**: replay the trace against 8 candidate configurations (fleet
   sizes 1–4 × edge-only vs cloud-budget policies) with successive halving,
   and report the cheapest candidate that serves the trace within SLO —
   verified on the full trace, never extrapolated from a prefix.

    PYTHONPATH=src python examples/plan_capacity.py
"""

from __future__ import annotations

import tempfile
import time
from pathlib import Path

import numpy as np

from repro.core.decision import DecisionEngine, MinLatencyPolicy
from repro.core.fit import build_fleet_predictor, fit_app
from repro.core.runtime import PlacementRuntime, TwinBackend
from repro.planner import SLO, Candidate, Planner, PolicySpec
from repro.trace import TraceWorkload, capture, load

CONFIGS = (1280, 1536, 1792, 2048)
N = 20_000
CHUNK = 8_192

twin, models = fit_app("STT", seed=0, n_inputs=120, configs=CONFIGS)


def make_runtime(fleet: dict[str, float], c_max: float = 0.0):
    pred = build_fleet_predictor(models, dict(fleet), configs=CONFIGS)
    eng = DecisionEngine(predictor=pred,
                         policy=MinLatencyPolicy(c_max=c_max, alpha=0.0))
    return PlacementRuntime(eng, TwinBackend(
        twin, seed=11, edge_names=tuple(fleet), edge_speed=fleet))


# ---------------------------------------------------------------- 1. record
fleet0 = {"edge0": 1.0, "edge1": 1.0}
run = make_runtime(fleet0).serve_stream(
    twin.poisson(seed=3).chunks(N, CHUNK), chunk_size=CHUNK,
    keep_tasks=False, keep_inputs=True)   # constant-memory, still capturable
trace = capture(run, app="STT")

with tempfile.TemporaryDirectory() as d:
    path = Path(d) / "stt_day.jsonl"
    trace.save(path)                      # JSONL: greppable, appendable
    trace = load(path)                    # validated + bit-exact reload
print(f"recorded {trace.n:,} arrivals over {trace.duration_ms / 3.6e6:.1f} h "
      f"(observed p99 "
      f"{np.percentile(trace.observed_latency_ms, 99):,.0f} ms)")

# ---------------------------------------------------------------- 2. replay
replay = make_runtime(fleet0).serve_stream(
    TraceWorkload(trace).chunks(chunk_size=CHUNK), chunk_size=CHUNK)
assert np.array_equal(replay.records.actual_latency_ms,
                      run.records.actual_latency_ms)
print("replay is bit-identical to the recorded run "
      f"(mean {replay.avg_actual_latency_ms:,.0f} ms)")

# ------------------------------------------------------------------ 3. plan
edge_only = PolicySpec(kind="min_latency", c_max=0.0)
with_cloud = PolicySpec(kind="min_latency", c_max=2.97e-5, alpha=0.02)
candidates = [
    Candidate.make(f"fleet-{k}-{tag}", k, policy=pol, cloud_configs=CONFIGS,
                   chunk_size=CHUNK, device_rate_per_hour=0.05)
    for k in (1, 2, 3, 4)
    for tag, pol in (("edge", edge_only), ("mixed", with_cloud))]

slo = SLO(latency_ms=40_000.0, target=0.95)
planner = Planner(trace, slo, fit_seed=0, n_inputs=120, fit_configs=CONFIGS)
t0 = time.perf_counter()
result = planner.plan(candidates, strategy="halving", rungs=3,
                      min_rung_n=2_048)
dt = time.perf_counter() - t0

print(f"\nwhat-if search: {len(candidates)} candidates, "
      f"{result.replayed_tasks:,} task-replays in {dt:.1f}s ({result.mode})")
for rung in result.rungs:
    print(f"  rung {rung['rung']} @ {rung['prefix_n']:,} tasks: "
          f"kept {rung['kept']}")
print(result.table())
best = result.best
print(f"\n=> provision {dict(best.candidate.fleet)} with the "
      f"{best.candidate.policy.kind} policy: ${best.total_cost:.4f} total, "
      f"{best.attainment:.2%} of tasks within {slo.latency_ms / 1e3:.0f} s")
