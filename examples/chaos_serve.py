"""Chaos twin: serve through a device outage with retry, failover, and
SLO-tiered load shedding — deterministically.

The scenario an operator plans for:

1. **Baseline** — the FD workload on a 3-device fleet, no faults, tasks
   split into two SLO tiers (interactive / batch). Everything meets SLO.
2. **Chaos** — the SAME workload, but a declarative ``FaultSpec`` takes one
   edge device down for the middle 30% of the run and makes one cloud
   config flaky (15% transient dispatch errors). The failure-aware runtime
   retries transients with exponential backoff, fails crashed work over to
   the next-best surviving target (re-entering the real placement path with
   the dead target masked), trips a circuit breaker on consecutive
   failures, and sheds batch-tier work when predicted latency blows the
   tier deadline — so the interactive tier still meets its SLO.
3. **Determinism** — the fault schedule is a counter-based pure function of
   (spec, dispatch times): the same seed reproduces the identical
   retry/failover/shed set, and the spec rides inside a captured trace
   (``fault_spec_of``) so any chaos run is replayable.
4. **Overload survival** — a 20x MMPP arrival burst. Reactively, the burst
   front eats a cold-start storm (the warm pool matches the quiet-phase
   rate). With ``PrewarmPolicy`` the streaming burst forecaster spots the
   regime switch a few arrivals in and spawns keep-alive containers ahead
   of the front, visibly cutting cold starts; with ``ReclamationPolicy``
   the same burst pressuring the top tier preempts placed lower-tier work
   off the hot device (demoting it one SLO class) instead of only shedding
   new arrivals at the admission door.

    PYTHONPATH=src python examples/chaos_serve.py
"""

from __future__ import annotations

import numpy as np

from repro.core.decision import DecisionEngine, MinLatencyPolicy
from repro.core.faults import (
    AdmissionPolicy,
    CircuitBreaker,
    FaultSpec,
    OutageWindow,
    RetryPolicy,
    SLOTier,
    TransientErrors,
)
from repro.core.decision import MinCostPolicy
from repro.core.fit import build_fleet_predictor, fit_app
from repro.core.overload import PrewarmPolicy, ReclamationPolicy
from repro.core.runtime import PlacementRuntime, TwinBackend
from repro.core.workload import BurstyWorkload
from repro.trace import capture, fault_spec_of

CONFIGS = (1280, 1536, 1792)
FLEET = {"edge0": 1.0, "edge1": 1.0, "edge2": 0.6}
N = 2_000
INTERACTIVE_SLO_MS = 15_000.0
BATCH_SLO_MS = 2_400.0          # tight: admission sheds batch work over it

twin, models = fit_app("FD", seed=0, n_inputs=120, configs=CONFIGS)

tasks = twin.workload(N, seed=3)
for t in tasks:
    t.tier = 0 if t.idx % 4 else 1     # 75% interactive, 25% batch
span = tasks[-1].arrival_ms
tiers = (SLOTier(INTERACTIVE_SLO_MS, sheddable=False),   # never shed
         SLOTier(BATCH_SLO_MS))                          # sheddable


def make_runtime(faults=None, failure_aware=False, policy=None, **overload):
    pred = build_fleet_predictor(models, dict(FLEET), configs=CONFIGS)
    eng = DecisionEngine(predictor=pred, policy=policy or MinLatencyPolicy(
        c_max=2.97e-5, alpha=0.02))
    backend = TwinBackend(twin, seed=11, edge_names=tuple(FLEET),
                          edge_speed=FLEET, faults=faults)
    if not failure_aware:
        return PlacementRuntime(eng, backend, **overload)
    return PlacementRuntime(
        eng, backend,
        retry=RetryPolicy(max_attempts=4, backoff_ms=50.0, backoff_mult=2.0),
        breaker=CircuitBreaker(threshold=3, probation_ms=30_000.0),
        admission=AdmissionPolicy(tiers=tiers, headroom=1.0))


def report(tag, res):
    print(f"{tag:>9}: interactive SLO "
          f"{res.slo_attainment(INTERACTIVE_SLO_MS, tier=0):6.2%}   "
          f"batch SLO {res.slo_attainment(BATCH_SLO_MS, tier=1):6.2%}   "
          f"retried {res.n_retried:3d}  failed {res.n_failed}  "
          f"shed {res.n_shed}")


# --------------------------------------------------------------- 1. baseline
base = make_runtime().serve(tasks)
report("baseline", base)

# ------------------------------------------------------------------ 2. chaos
spec = FaultSpec(
    seed=7,
    outages=[OutageWindow("edge1", 0.35 * span, 0.65 * span)],  # mid-run
    transient=[TransientErrors("1792", 0.15)],
)
rt = make_runtime(faults=spec, failure_aware=True)
chaos = rt.serve(tasks)
report("chaos", chaos)
assert chaos.slo_attainment(INTERACTIVE_SLO_MS, tier=0) >= 0.99, \
    "the interactive tier must ride through the outage"
print(f"           circuit breaker opened {rt.health.n_opens}x; "
      f"{(chaos.records.attempts > 1).sum()} tasks re-dispatched "
      f"(max {chaos.records.attempts.max()} attempts)")

# ---------------------------------------------------------- 3. deterministic
again = make_runtime(faults=spec, failure_aware=True).serve(tasks)
assert np.array_equal(chaos.records.actual_latency_ms,
                      again.records.actual_latency_ms)
assert np.array_equal(chaos.records.attempts, again.records.attempts)
assert np.array_equal(chaos.records.shed, again.records.shed)
print("rerun with the same spec: identical fault schedule, retries, and "
      "shed set")

trace = capture(chaos, app="FD", faults=spec)
assert fault_spec_of(trace) == spec
print("fault spec rides inside the captured trace — chaos runs replay")

# ------------------------------------------------ 4a. burst: predictive prewarm
burst_wl = BurstyWorkload(rate_per_s=2.0, size_sampler=twin.sample_input,
                          burst_multiplier=20.0, mean_quiet_s=20.0,
                          mean_burst_s=5.0, seed=3)
burst_tasks = burst_wl.generate(400)
reactive = make_runtime().serve(burst_tasks)
rt_pw = make_runtime(prewarm=PrewarmPolicy(count=4))
warmed = rt_pw.serve(burst_tasks)
cold_re = int(reactive.records.actual_cold.sum())
cold_pw = int(warmed.records.actual_cold.sum())
print(f"\n20x burst, reactive: {cold_re} cold starts; predictive prewarm: "
      f"{cold_pw} ({rt_pw.overload.forecaster.n_triggers} burst(s) "
      f"forecast, {len(rt_pw.overload.prewarm_log)} containers spawned, "
      f"{rt_pw.overload.n_extensions} keep-alive extensions)")
assert cold_pw < cold_re, "pre-warming must beat reacting to the burst"

# --------------------------------------------- 4b. burst: fair-share reclaim
for i, t in enumerate(burst_tasks):
    t.tier = i % 3              # interactive / standard / batch
recl = ReclamationPolicy(tiers=(SLOTier(3_000.0, sheddable=False),
                                SLOTier(2_500.0), SLOTier(2_000.0)),
                         shares=(2.0, 1.0, 1.0))
rt_rc = make_runtime(policy=MinCostPolicy(deadline_ms=3_000.0),
                     reclamation=recl)
reclaimed = rt_rc.serve(burst_tasks)
n_moved = sum(1 for e in rt_rc.overload.reclaim_log if e[6])
print(f"under tier-0 pressure: {len(rt_rc.overload.reclaim_log)} lower-tier "
      f"tasks preempted ({n_moved} moved off the hot device, "
      f"{reclaimed.n_downgraded} demoted one SLO class, 0 shed)")
assert len(rt_rc.overload.reclaim_log) > 0
