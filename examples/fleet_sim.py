"""Fleet quickstart: place a bursty workload across a 3-device edge fleet.

The paper assumes ONE smart edge device; this example runs its framework over
an ``EdgeFleet`` — two full-speed cameras plus one older half-speed unit —
with the cloud configs as overflow. It compares:

- the single-edge configuration (the paper's setup),
- round-robin device balancing (backlog-blind baseline),
- least-predicted-wait balancing (the default ``EdgeBalancer``),

on skewed (bursty) arrivals, then prints the per-device utilization and
queue-wait summaries the fleet metrics expose.

    PYTHONPATH=src python examples/fleet_sim.py
"""

from repro.core.decision import (
    DecisionEngine,
    LeastPredictedWaitBalancer,
    MinLatencyPolicy,
    RoundRobinBalancer,
)
from repro.core.fit import build_fleet_predictor, build_predictor, fit_app
from repro.core.runtime import PlacementRuntime, TwinBackend
from repro.core.workload import BurstyWorkload

CONFIGS = (1280, 1536, 1792, 2048)
DEVICES = {"edge0": 1.0, "edge1": 1.0, "edge2": 0.6}  # one slow straggler
C_MAX = 2e-6  # edge-first budget: bursts must be absorbed by the devices

print("fitting IR models...")
twin, models = fit_app("IR", seed=0, n_inputs=150, configs=CONFIGS)
tasks = BurstyWorkload(rate_per_s=4.0, size_sampler=twin.sample_input,
                       burst_multiplier=6.0, mean_quiet_s=15.0,
                       mean_burst_s=6.0, seed=7).generate(3000)


def fleet(balancer):
    pred = build_fleet_predictor(models, dict(DEVICES), configs=CONFIGS)
    eng = DecisionEngine(predictor=pred,
                         policy=MinLatencyPolicy(c_max=C_MAX, alpha=0.02),
                         balancer=balancer)
    backend = TwinBackend(twin, seed=11, edge_names=tuple(DEVICES),
                          edge_speed=DEVICES)
    return PlacementRuntime(eng, backend).serve(tasks)


def single():
    pred = build_predictor(models, configs=CONFIGS)
    eng = DecisionEngine(predictor=pred,
                         policy=MinLatencyPolicy(c_max=C_MAX, alpha=0.02))
    return PlacementRuntime(eng, TwinBackend(twin, seed=11)).serve(tasks)


print(f"\n{'configuration':<24} {'mean s':>8} {'p99 s':>8} {'edge#':>6}")
results = {}
for name, run in [("single edge (paper)", single),
                  ("fleet-3 round-robin", lambda: fleet(RoundRobinBalancer())),
                  ("fleet-3 least-wait", lambda: fleet(LeastPredictedWaitBalancer()))]:
    res = run()
    results[name] = res
    print(f"{name:<24} {res.avg_actual_latency_ms / 1e3:>8.1f} "
          f"{res.p99_actual_latency_ms / 1e3:>8.1f} {res.n_edge:>6d}")

print("\nleast-wait fleet balance (note the slow device taking fewer tasks):")
print(results["fleet-3 least-wait"].device_table())
