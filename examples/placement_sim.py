"""Sweep study: how the two knobs of the paper's framework behave.

Reproduces Fig. 5 (cost vs deadline δ) and Fig. 6 (latency vs α) behavior for
one app each, printing ASCII curves. Faster than benchmarks/run.py — good for
interactive exploration.

    PYTHONPATH=src python examples/placement_sim.py
"""

import numpy as np

from repro.core.decision import DecisionEngine, MinCostPolicy, MinLatencyPolicy
from repro.core.fit import build_predictor, fit_app
from repro.core.runtime import PlacementRuntime, TwinBackend


def bar(x, scale, width=40):
    n = int(min(x / scale, 1.0) * width)
    return "#" * n


print("fitting STT models...")
twin, models = fit_app("STT", seed=0, n_inputs=300,
                       configs=(768, 1152, 1280, 1664))
tasks = twin.workload(300, seed=5)

print("\nFig.5-style: total cost and edge executions vs deadline δ (STT)")
print(f"{'δ (s)':>6} {'cost $':>10} {'edge#':>6}")
for d in (4500, 5000, 5500, 6000, 6500, 7000):
    pred = build_predictor(models, configs=(768, 1152, 1280, 1664))
    eng = DecisionEngine(predictor=pred, policy=MinCostPolicy(float(d)))
    res = PlacementRuntime(eng, TwinBackend(twin, seed=9)).serve(tasks)
    print(f"{d/1e3:>6.1f} {res.total_actual_cost:>10.6f} {res.n_edge:>6d} "
          f"|{bar(res.n_edge, 300)}")

print("\nFig.6-style: average latency vs α (STT, C_max=$3.07e-5)")
print(f"{'α':>6} {'avg s':>8} {'budget rem%':>12}")
for a in (0.0, 0.01, 0.02, 0.03, 0.05, 0.1):
    pred = build_predictor(models, configs=(1152, 1280, 1664))
    eng = DecisionEngine(predictor=pred,
                         policy=MinLatencyPolicy(3.0747e-5, a))
    res = PlacementRuntime(eng, TwinBackend(twin, seed=9)).serve(tasks)
    rem = 100 - res.pct_budget_used
    print(f"{a:>6.2f} {res.avg_actual_latency_ms/1e3:>8.3f} {rem:>11.1f}% "
          f"|{bar(res.avg_actual_latency_ms, 20e3)}")
