"""Cross-application streaming serve: IR + FD + STT as parallel shards.

The paper evaluates each application in isolation; real edge platforms run
long-lived mixes (EdgeBench's trio). This example:

1. streams ONE application through ``PlacementRuntime.serve_stream`` and
   shows the parity guarantee — the chunked result is bit-identical to the
   one-shot ``serve(batched=True)``, at O(chunk) working memory;
2. serves all three applications as ``AppShard``s through ``serve_sharded``
   — each shard owns its fitted Predictor, its policy budget, and its own
   3-device fleet partition — and prints the cross-app report.

    PYTHONPATH=src python examples/multi_app_serve.py
"""

from __future__ import annotations

import functools
import time

import numpy as np

from repro.core.decision import DecisionEngine, MinLatencyPolicy
from repro.core.fit import build_fleet_predictor, fit_app
from repro.core.multiapp import AppShard, serve_sharded
from repro.core.runtime import PlacementRuntime, TwinBackend

CONFIGS = (1280, 1536, 1792)
FLEET = {"edge0": 1.0, "edge1": 1.0, "edge2": 0.6}
N_PER_APP = 100_000
CHUNK = 16_384

SETUPS = {app: fit_app(app, seed=0, n_inputs=120, configs=CONFIGS)
          for app in ("IR", "FD", "STT")}


def make_runtime(app: str, c_max: float = 0.0) -> PlacementRuntime:
    twin, models = SETUPS[app]
    pred = build_fleet_predictor(models, dict(FLEET), configs=CONFIGS)
    eng = DecisionEngine(predictor=pred,
                         policy=MinLatencyPolicy(c_max=c_max, alpha=0.0))
    backend = TwinBackend(twin, seed=7, edge_names=tuple(FLEET),
                          edge_speed=FLEET)
    return PlacementRuntime(eng, backend)


def make_workload(app: str, n: int = N_PER_APP):
    # a generator of columnar TaskChunks: O(chunk) live tasks, bit-identical
    # to the list the same workload's generate(n) would build
    return SETUPS[app][0].poisson(seed=3).chunks(n, chunk_size=CHUNK)


def main() -> None:
    # ---- 1. streaming parity: chunked ≡ one-shot, per record --------------
    tasks = SETUPS["STT"][0].workload(20_000, seed=3)
    one = make_runtime("STT").serve(tasks, batched=True)
    streamed = make_runtime("STT").serve_stream(tasks, chunk_size=1024)
    assert list(streamed.records.targets) == list(one.records.targets)
    assert np.array_equal(streamed.records.actual_latency_ms,
                          one.records.actual_latency_ms)
    assert np.array_equal(streamed.records.completion_ms,
                          one.records.completion_ms)
    print("serve_stream(chunk=1024) ≡ serve(batched=True): "
          f"{streamed.n:,} records identical\n")

    # ---- 2. the cross-application fleet ----------------------------------
    shards = [AppShard(name=app,
                       runtime=functools.partial(make_runtime, app),
                       workload=functools.partial(make_workload, app),
                       chunk_size=CHUNK)
              for app in SETUPS]
    t0 = time.perf_counter()
    seq = serve_sharded(shards, parallel=False)
    seq_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    par = serve_sharded(shards)  # threads; use_processes=True for isolation
    par_s = time.perf_counter() - t0

    for app in SETUPS:  # independent shards: scheduling perturbs nothing
        assert np.array_equal(par.results[app].records.actual_latency_ms,
                              seq.results[app].records.actual_latency_ms)

    print(f"3 apps × {N_PER_APP:,} tasks   sequential {seq_s:.2f}s   "
          f"parallel {par_s:.2f}s\n")
    print(par.table())
    print("\nper-app stream stats:")
    for app, st in par.stream_stats.items():
        print(f"  {app:<4} {st}")


if __name__ == "__main__":
    main()
