"""Device-resident placement: the jit-compiled JAX predict→place pipeline.

Serves the same bursty stream three ways — the numpy columnar oracle,
``array_backend="jax_interpret"`` (the bit-parity audit mode), and compiled
``array_backend="jax"`` — and verifies the parity contract on the spot:
interpret mode must match the oracle bit-for-bit on every record column,
compiled mode must make identical decisions with floats within tolerance.

Then demonstrates persistent residency: a 3-chunk resident stream places
every chunk with the CIL pools / surplus bank / edge horizons held
device-side (one host materialization total, at stream end), matches the
oracle's decisions, and — rerun same-shape on the same engine — reuses
every jit cache entry (no retrace).

    PYTHONPATH=src python examples/jax_serve.py
"""

import time

import numpy as np

from repro.core import jax_core
from repro.core.decision import DecisionEngine, MinLatencyPolicy
from repro.core.fit import build_fleet_predictor, fit_app
from repro.core.runtime import PlacementRuntime, TwinBackend
from repro.core.workload import BurstyWorkload

N_TASKS = 2_000
CHUNK = 512
CONFIGS = (1280, 1536, 1792)
FLEET = {"edge0": 1.0, "edge1": 1.0, "edge2": 0.6}
C_MAX = 6e-6            # $/task budget (Alg. 1)
ALPHA = 0.05

print("fitting IR component models (twin ground truth)...")
twin, models = fit_app("IR", seed=0, n_inputs=120, configs=CONFIGS)
tasks = BurstyWorkload(rate_per_s=4.0, size_sampler=twin.sample_input,
                       burst_multiplier=8.0, mean_quiet_s=10.0,
                       mean_burst_s=6.0, seed=31).generate(N_TASKS)


def runtime():
    pred = build_fleet_predictor(models, dict(FLEET), configs=CONFIGS)
    eng = DecisionEngine(predictor=pred,
                         policy=MinLatencyPolicy(c_max=C_MAX, alpha=ALPHA))
    backend = TwinBackend(twin, seed=11, edge_names=tuple(FLEET),
                          edge_speed=FLEET)
    return PlacementRuntime(eng, backend)


def serve(backend):
    rt = runtime()
    t0 = time.perf_counter()
    res = rt.serve_stream(tasks, chunk_size=CHUNK, array_backend=backend)
    dt = time.perf_counter() - t0
    return res, dt, rt.engine


print(f"serving {N_TASKS} bursty tasks, chunk={CHUNK}, 3-device fleet...")
ref, t_np, _ = serve("numpy")
interp, t_it, eng_it = serve("jax_interpret")
comp, t_jx, eng_jx = serve("jax")

COLS = ("predicted_latency_ms", "predicted_cost", "actual_latency_ms",
        "actual_cost", "allowed_cost", "completion_ms", "queue_wait_ms",
        "exec_ms", "predicted_cold", "actual_cold", "feasible")

bit_equal = (list(ref.records.targets) == list(interp.records.targets)
             and all(np.array_equal(getattr(ref.records, c),
                                    getattr(interp.records, c))
                     for c in COLS))
dec_equal = list(ref.records.targets) == list(comp.records.targets)
close = all(np.allclose(getattr(ref.records, c).astype(float),
                        getattr(comp.records, c).astype(float), rtol=1e-9)
            for c in COLS)
assert bit_equal, "interpret mode must be bit-identical to the numpy oracle"
assert dec_equal and close, "compiled mode must be decision-identical"

core = jax_core.core_for(eng_jx)
print(f"\nnumpy oracle          : {t_np:.2f} s")
print(f"jax_interpret (audit) : {t_it:.2f} s  bit-identical: {bit_equal}")
print(f"jax (compiled)        : {t_jx:.2f} s  decision-identical: "
      f"{dec_equal}  floats close: {close}")
print(f"fixed-point passes    : {eng_jx.jax_stats['passes']} "
      f"(last chunk, rows={eng_jx.jax_stats['rows']})")
print(f"jit cache entries     : {core.compile_stats()}")
print(f"avg latency           : {ref.avg_actual_latency_ms:.1f} ms   "
      f"total cost: ${ref.total_actual_cost:.6f}")

# --- persistent residency (3-chunk resident stream) -------------------------
# Stream state stays device-side across chunks: no host commit at chunk
# boundaries, one materialization at stream end. A same-shape continuation
# stream on the same engine (arrivals keep moving forward — replaying past
# arrivals would cold-start into ever-larger pools) must reuse every jit
# cache entry.
demo = BurstyWorkload(rate_per_s=4.0, size_sampler=twin.sample_input,
                      burst_multiplier=8.0, mean_quiet_s=10.0,
                      mean_burst_s=6.0, seed=32).generate(6 * CHUNK)
rt_ref, rt_res = runtime(), runtime()
ref_r = rt_ref.serve_stream(demo[:3 * CHUNK], chunk_size=CHUNK)
res_r = rt_res.serve_stream(demo[:3 * CHUNK], chunk_size=CHUNK,
                            array_backend="jax")
r = rt_res.stream_stats["residency"]
assert list(ref_r.records.targets) == list(res_r.records.targets), \
    "resident stream diverged from the numpy oracle"
assert r["enabled"] and r["resident_chunks"] == 3
assert r["chunk_commits"] == 0 and r["state_syncs"] == 1

core_r = jax_core.core_for(rt_res.engine)
stats0 = core_r.compile_stats()
rt_res.serve_stream(demo[3 * CHUNK:], chunk_size=CHUNK, array_backend="jax")
no_retrace = core_r.compile_stats() == stats0
assert no_retrace, "same-shape continuation stream retraced"
print(f"resident stream       : 3/3 chunks device-resident, "
      f"{r['state_syncs']} host sync (stream end), "
      f"{r['chunk_commits']} chunk commits, prefetched {r['prefetched']}, "
      f"no-retrace continuation: {no_retrace}")

print("\nOn CPU the compiled path loses to numpy (XLA scan overhead); on an "
      "accelerator\nthe same code is the fast path — see "
      "benchmarks/bench_runtime.py sections 9 and 11.")
