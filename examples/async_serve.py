"""Async serving: the event-driven driver on the live pool and on the twin.

Part 1 — the LIVE pool: ``serve_async`` over real compiled executors runs a
genuinely concurrent dispatch loop (one worker thread per edge device and
per cloud config, completion queue, per-executor compile guard). With the
paper's WAN legs emulated as real waits (``NetworkProfile``), the per-device
workers overlap each other's network time and the wall clock drops well
below sequential dispatch. (This part runs first: it measures real wall
time, and the cleanest process state gives the fairest overlap numbers.)

Part 2 — the TWIN: the same ``serve_async`` call fans a bursty 3-device
fleet workload out to per-target workers interleaved on the virtual-clock
event heap (``repro.core.events``) and merges the outcome arrays back into
the same columnar ``RecordBatch`` as ``serve(batched=True)``. The two
results are METRIC-IDENTICAL — that is the parity guarantee the
event-driven refactor ships with (the heap changes *when* work is
simulated, never the math).

    PYTHONPATH=src python examples/async_serve.py
"""

import time

from repro.configs import smoke_config
from repro.core.decision import DecisionEngine, MinLatencyPolicy
from repro.core.fit import build_fleet_predictor, fit_app
from repro.core.runtime import PlacementRuntime, TwinBackend
from repro.core.workload import BurstyWorkload
from repro.serving.executors import NetworkProfile, SliceSpec
from repro.serving.placement import (
    calibrate_catalog,
    llm_workload,
    make_live_runtime,
)

CONFIGS = (1280, 1536, 1792)
DEVICES = {"edge0": 1.0, "edge1": 1.0, "edge2": 0.6}

# ------------------------------------------------------- live overlap demo
print("calibrating the live catalog (real compiles)...")
cfg = smoke_config("llama3.2-1b").with_updates(
    n_layers=2, d_model=32, d_ff=64, vocab=64, n_heads=2, n_kv_heads=2,
    head_dim=16)
cat = calibrate_catalog(cfg, [SliceSpec("s2", 2, tokens_per_step=4),
                              SliceSpec("s8", 8, tokens_per_step=4)],
                        n_tasks=6, n_cold=1, seed=0, mean_tokens=16.0)
requests = llm_workload(60, rate_per_s=2000.0, seed=4, mean_tokens=16.0)
net = NetworkProfile(base_ms=40.0)  # the paper's IoT-upload leg, emulated


def live():
    return make_live_runtime(cat, MinLatencyPolicy(c_max=0.0, alpha=0.0),
                             n_edge_devices=3, network=net)


# provision (and compile) both fleets BEFORE the timers: the comparison is
# dispatch overlap, not provisioning cost
rt_seq, rt_async = live(), live()

t0 = time.perf_counter()
rt_seq.serve(requests)
seq_s = time.perf_counter() - t0

t0 = time.perf_counter()
res = rt_async.serve_async(requests)
async_s = time.perf_counter() - t0

print(f"live: sequential {seq_s:5.2f}s   async {async_s:5.2f}s   "
      f"overlap speedup {seq_s / async_s:4.2f}x")
print(res.device_table())

# ------------------------------------------------------------ twin parity
print("\nfitting FD models...")
twin, models = fit_app("FD", seed=0, n_inputs=150, configs=CONFIGS)
tasks = BurstyWorkload(rate_per_s=4.0, size_sampler=twin.sample_input,
                       burst_multiplier=6.0, mean_quiet_s=15.0,
                       mean_burst_s=6.0, seed=7).generate(5000)


def runtime():
    eng = DecisionEngine(
        predictor=build_fleet_predictor(models, dict(DEVICES), configs=CONFIGS),
        policy=MinLatencyPolicy(c_max=1e-5, alpha=0.02))
    return PlacementRuntime(eng, TwinBackend(twin, seed=11,
                                             edge_names=tuple(DEVICES),
                                             edge_speed=dict(DEVICES)))


batched = runtime().serve(tasks)

rt = runtime()
# the per-target worker queues the async driver consumes, by target_codes
plan = rt.engine.place_many(tasks, edge_queues=rt.edge_queues)
for name, rows in sorted(plan.rows_by_target().items()):
    print(f"  worker {name:<6} pulls {rows.shape[0]:>5} rows")
event_driven = runtime().serve_async(tasks)

assert event_driven.total_actual_cost == batched.total_actual_cost
assert event_driven.avg_actual_latency_ms == batched.avg_actual_latency_ms
assert event_driven.p99_actual_latency_ms == batched.p99_actual_latency_ms
print(f"twin parity: serve_async == serve(batched=True)  "
      f"(mean {event_driven.avg_actual_latency_ms:,.0f} ms, "
      f"p99 {event_driven.p99_actual_latency_ms:,.0f} ms, "
      f"cost ${event_driven.total_actual_cost:.4f})")
