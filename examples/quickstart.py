"""Quickstart: the paper's framework in ~60 lines.

Fits the performance models for the Face Detection app against the AWS twin
(paper Sec. IV), then runs both placement policies (Sec. III-B) through the
event-driven simulator (Sec. VI-A) and prints the headline metrics.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core.decision import DecisionEngine, MinCostPolicy, MinLatencyPolicy
from repro.core.fit import build_predictor, fit_app
from repro.core.runtime import PlacementRuntime, TwinBackend

# 1. Collect measurements from the (simulated) AWS environment and fit the
#    component models: upload/ridge, GBRT compute, normal start/store.
print("fitting performance models for FD (dlib face detection)...")
twin, models = fit_app("FD", seed=0, n_inputs=400,
                       configs=(1280, 1408, 1536, 1664, 2048))
print(f"  cloud end-to-end MAPE: {models.cloud_e2e_mape:.2f}%   "
      f"edge: {models.edge_e2e_mape:.2f}%   (paper Table II: 13.24 / 3.78)")

# 2. A fresh 600-input Poisson workload (4 frames/s smart camera).
tasks = twin.workload(600, seed=42)

# 3a. Minimize latency subject to a per-task budget (paper Alg. 1).
#     The unified runtime: ONE serve loop over a pluggable execution backend
#     (here the AWS twin; repro.serving swaps in the live executor pool).
predictor = build_predictor(models, configs=(1536, 1664, 2048))
engine = DecisionEngine(predictor=predictor,
                        policy=MinLatencyPolicy(c_max=2.96997e-5, alpha=0.02))
res = PlacementRuntime(engine, TwinBackend(twin, seed=7)).serve(tasks)
print(f"\nmin-latency: avg {res.avg_actual_latency_ms/1e3:.3f}s/task, "
      f"pred err {res.latency_error_pct:.2f}%, "
      f"budget used {res.pct_budget_used:.1f}%, "
      f"warm/cold mispredictions {res.n_warm_cold_mismatches}/{res.n}")

# 3b. Minimize cost subject to a 4.5 s deadline.
predictor = build_predictor(models, configs=(1280, 1408, 1664))
engine = DecisionEngine(predictor=predictor, policy=MinCostPolicy(4500.0))
res = PlacementRuntime(engine, TwinBackend(twin, seed=7)).serve(tasks)
print(f"min-cost:    total ${res.total_actual_cost:.6f}, "
      f"pred err {res.cost_error_pct:.2f}%, "
      f"deadline violations {res.pct_deadline_violated:.2f}%")

# 4. The punchline (paper Sec. VI-B): dynamic placement vs edge-only.
engine0 = DecisionEngine(predictor=build_predictor(models, configs=(1536,)),
                         policy=MinLatencyPolicy(c_max=0.0, alpha=0.0))
res0 = PlacementRuntime(engine0, TwinBackend(twin, seed=7)).serve(tasks)
print(f"\nedge-only:   avg {res0.avg_actual_latency_ms/1e3:.1f}s/task "
      f"(queueing collapse) → dynamic placement is "
      f"{res0.avg_actual_latency_ms/res.avg_actual_latency_ms:.0f}x faster")
